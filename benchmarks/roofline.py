"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json (written by repro.launch.dryrun), derives per
(arch x shape x mesh):
  - the three roofline terms (compute / memory / collective, seconds/chip)
  - the dominant bottleneck
  - MODEL_FLOPS = 6 N D (dense) or 6 N_active D (MoE) per train round /
    2 N D per generated/prefilled token for serving
  - MODEL_FLOPS / HLO_FLOPS (useful-compute ratio; catches remat/dispatch waste)
"""
from __future__ import annotations

import glob
import json
import os

from repro.config import INPUT_SHAPES
from repro.configs import get_config


def model_flops(arch: str, shape_name: str, num_clients: int, k0: int = 5) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        # one FedGiA round: ONE fwd+bwd over the global batch (C2: the k0
        # ADMM iterations are gradient-free) => 6 * N_active * tokens
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per request
    return 2.0 * n_active * shape.global_batch


def load_records(path: str = "results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def analyse(recs, chips_map={"16x16": 256, "2x16x16": 512}):
    rows = []
    for r in recs:
        chips = chips_map[r["mesh"]]
        mf_total = model_flops(r["arch"], r["shape"], r.get("num_clients", 16))
        mf_per_chip = mf_total / chips
        hlo = r["per_device"]["flops"]
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": r["mesh"],
            "algo": r["algo"],
            "collapsed": r.get("collapsed", True),
            "t_compute_ms": r["roofline"]["t_compute_s"] * 1e3,
            "t_memory_ms": r["roofline"]["t_memory_s"] * 1e3,
            "t_collective_ms": r["roofline"]["t_collective_s"] * 1e3,
            "bottleneck": r["roofline"]["bottleneck"],
            "model_flops_per_chip": mf_per_chip,
            "hlo_flops_per_chip": hlo,
            "useful_ratio": (mf_per_chip / hlo) if hlo else 0.0,
            "fit_gib": (r["per_device"]["argument_bytes"]
                        + r["per_device"]["output_bytes"]
                        + r["per_device"]["temp_bytes"]) / 2**30,
        })
    return rows


def main():
    recs = load_records()
    if not recs:
        print("no dry-run records found — run: python -m repro.launch.dryrun --all")
        return []
    # baseline records only (perf-variant reruns live in §Perf)
    base, seen = [], set()
    for r in recs:
        if r.get("fsdp") or r.get("replicate_params") or not r.get("collapsed", True):
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        if key in seen:
            continue
        seen.add(key)
        base.append(r)
    rows = analyse(base)
    print("arch,shape,mesh,algo,t_compute_ms,t_memory_ms,t_collective_ms,"
          "bottleneck,useful_ratio,fit_GiB")
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['algo']},"
              f"{r['t_compute_ms']:.3f},{r['t_memory_ms']:.3f},"
              f"{r['t_collective_ms']:.3f},{r['bottleneck']},"
              f"{r['useful_ratio']:.3f},{r['fit_gib']:.2f}")
    return rows


if __name__ == "__main__":
    main()
