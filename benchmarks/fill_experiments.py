"""Render §Dry-run and §Roofline of EXPERIMENTS.md from results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.fill_experiments
"""
from __future__ import annotations

import json

from benchmarks.roofline import analyse, load_records, model_flops
from repro.configs import list_architectures
from repro.config import INPUT_SHAPES

MARK_DRY = "<!-- DRYRUN_SUMMARY -->"
MARK_ROOF = "<!-- ROOFLINE_TABLE -->"


def fmt_ms(x):
    return f"{x:9.1f}"


def render(recs):
    base = [r for r in recs if r["algo"] in ("fedgia", "serve")
            and r.get("collapsed", True) and not r.get("fsdp")
            and not r.get("replicate_params")]
    rows = analyse(base)

    # ---- dry-run summary: compile matrix + memory fit
    n1 = sum(1 for r in base if r["mesh"] == "16x16")
    n2 = sum(1 for r in base if r["mesh"] == "2x16x16")
    lines = [f"Compiled OK: {n1}/40 single-pod, {n2}/40 multi-pod.", ""]
    lines.append("Per-chip memory (args+outputs+temps, GiB) from "
                 "`compiled.memory_analysis()` of the PRODUCTION (scan+remat) "
                 "lowering — v5e budget is 16 GiB:")
    lines.append("")
    lines.append("| arch | train_4k | prefill_32k | decode_32k | long_500k |")
    lines.append("|---|---|---|---|---|")
    fit = {(r["arch"], r["shape"], r["mesh"]): r for r in rows}
    for arch in list_architectures():
        cells = []
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            r = fit.get((arch, shape, "16x16"))
            if r is None:
                cells.append("—")
                continue
            g = r["fit_gib"]
            cells.append(f"{g:.1f}" + (" ⚠" if g > 16 else ""))
        lines.append(f"| {arch} | " + " | ".join(cells) + " |")
    lines.append("")
    lines.append("⚠ = exceeds one v5e chip's 16 GiB HBM as configured; "
                 "every such case is addressed or explained in §Perf / "
                 "DESIGN §5b (FedGiA's per-client state floor; unfused "
                 "bytes upper bound).")
    dry = "\n".join(lines)

    # ---- roofline table
    rl = ["| arch | shape | mesh | compute ms | memory ms | collective ms |"
          " bottleneck | useful ratio |",
          "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        rl.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {r['t_compute_ms']:.1f} | {r['t_memory_ms']:.1f} |"
            f" {r['t_collective_ms']:.1f} | {r['bottleneck']} |"
            f" {r['useful_ratio']:.2f} |"
        )
    rl.append("")
    rl.append("`useful ratio` = MODEL_FLOPS / HLO_FLOPS per chip, where "
              "MODEL_FLOPS = 6·N_active·tokens (train round; FedGiA computes "
              "ONE gradient per round) or 2·N_active·tokens (serving). "
              "Ratios < 1 expose non-model compute: the quadratic attention "
              "term (dominant at 32k prefill), MoE dispatch overhead "
              "(capacity factor 1.25), and non-causal-skipped score blocks "
              "in the jnp streaming attention (the Pallas kernel skips them)."
              " Per-(arch,mesh) bottleneck notes follow in §Roofline notes.")
    roof = "\n".join(rl)
    return dry, roof


def main():
    recs = load_records()
    dry, roof = render(recs)
    with open("EXPERIMENTS.md") as f:
        s = f.read()
    s = s.replace(MARK_DRY, dry)
    s = s.replace(MARK_ROOF, roof)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(s)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
