"""Paper Fig. 2: effect of k0 on CR and wall time — CR decline then
stabilise as k0 rises; time grows with k0 (FedGiA_G more than FedGiA_D)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_algorithm

K0S = [1, 2, 4, 6, 8, 10, 14, 20]
TRIALS = 2


def run():
    rows = []
    for variant in ("fedgia_d", "fedgia_g"):
        for k0 in K0S:
            rs = [run_algorithm(variant, "linreg", k0, seed=s) for s in range(TRIALS)]
            rows.append({
                "variant": variant, "k0": k0,
                "cr": float(np.mean([r["cr"] for r in rs])),
                "time_s": float(np.mean([r["time_s"] for r in rs])),
            })
    return rows


def main():
    rows = run()
    print("variant,k0,CR,time_s")
    for r in rows:
        print(f"{r['variant']},{r['k0']},{r['cr']:.1f},{r['time_s']:.3f}")
    for variant in ("fedgia_d", "fedgia_g"):
        crs = [r["cr"] for r in rows if r["variant"] == variant]
        assert crs[0] >= crs[-1], f"{variant}: CR should decline with k0"
    return rows


if __name__ == "__main__":
    main()
