"""Partial-participation benchmark (paper Fig. 3 mechanism, in-engine).

Sweeps the selection fraction alpha with the engine-level uniform
participation policy — the mask is drawn ON DEVICE inside the compiled
scan, so the sweep exercises the exact mechanism the paper's efficiency
claims rest on (only |C| = alpha*m clients run the inexact-ADMM branch).

Two parts:
  * scan path (this process): alpha sweep for FedGiA_D and SCAFFOLD to
    the paper's stopping rule; reports CR / wall time / final objective.
  * sharded path (subprocess, 8 fake CPU devices): the same sweep with
    the client axis sharded over the mesh's `data` axis, asserting (a) it
    matches the single-device run and (b) the masked round issues exactly
    as many MODEL-SIZE all-reduces as the unmasked one — eq. (11)'s
    single psum per round is preserved; masking adds only a scalar
    participant-count rider.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import textwrap

import jax

from benchmarks.common import M_CLIENTS, make_problem
from repro.config import FedConfig
from repro.core import UniformParticipation, make_algorithm, run_rounds

ALPHAS = [0.1, 0.25, 0.5, 1.0]
K0 = 10
ALGOS = {
    "fedgia_d": dict(algorithm="fedgia", sigma_t=0.15, h_policy="diag_ema",
                     alpha=1.0),  # branch split comes from the engine mask
    "scaffold": dict(algorithm="scaffold", lr=0.01),
}

_SHARDED_SCRIPT = textwrap.dedent(
    """
    import re
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import FedConfig
    from repro.core import UniformParticipation, make_algorithm, run_rounds
    from repro.core import engine
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)
    fed = FedConfig(algorithm="fedgia", num_clients=m, k0=5, alpha=1.0,
                    sigma_t=0.3, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    s0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                   init_batch=batch)

    def model_size_all_reduces(masked):
        rf = engine.make_round_fn(algo, mesh, masked=masked)
        st, b = engine.shard_inputs(algo, s0, batch, mesh)
        args = (st, b) + ((jnp.ones((m,), bool),) if masked else ())
        txt = jax.jit(rf).lower(*args).compile().as_text()
        shapes = re.findall(r"= (\\S+) all-reduce\\(", txt)
        return sum(1 for s in shapes if re.search(r"\\[\\d", s))

    plain, masked = model_size_all_reduces(False), model_size_all_reduces(True)
    assert masked == plain, (
        f"masked round changed the model-size all-reduce count: "
        f"{plain} -> {masked}")

    print("alpha,selected,rounds,sharded_obj,single_dev_obj")
    for alpha in (0.25, 0.5, 1.0):
        pol = UniformParticipation(m, alpha, seed=2)
        ref = run_rounds(algo, s0, batch, 20, scan=True, chunk_size=10,
                         participation=pol)
        res = run_rounds(algo, s0, batch, 20, scan=True, chunk_size=10,
                         participation=pol, mesh=mesh)
        for k in ref.history:
            np.testing.assert_allclose(res.history[k], ref.history[k],
                                       rtol=1e-5, atol=1e-6, err_msg=k)
        print(f"{alpha},{int(res.history['selected'][0])},{res.rounds_run},"
              f"{float(res.history['f_xbar'][-1]):.6f},"
              f"{float(ref.history['f_xbar'][-1]):.6f}")
    print(f"PARTICIPATION_SHARDED_OK model_size_all_reduces={masked}")
    """
)


def run():
    rows = []
    model, batch, tol = make_problem("linreg", 0)
    for algo_key, hp in ALGOS.items():
        fed = FedConfig(num_clients=M_CLIENTS, k0=K0, **hp)
        algo = make_algorithm(fed, model.loss, model=model)
        state = algo.init(model.init(jax.random.PRNGKey(0)),
                          jax.random.PRNGKey(1), init_batch=batch)
        for alpha in ALPHAS:
            pol = UniformParticipation(M_CLIENTS, alpha, seed=0)
            res = run_rounds(algo, state, batch, 500, tol=tol,
                             participation=pol)
            rows.append({
                "algo": algo_key,
                "alpha": alpha,
                "selected": int(res.history["selected"][0]),
                "cr": 2 * res.rounds_run,
                "time_s": res.wall_s,
                "obj": float(res.history["f_xbar"][-1]),
                "converged": res.stopped_early,
            })
    return rows


def run_sharded() -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "PARTICIPATION_SHARDED_OK" in out.stdout, out.stdout + out.stderr
    return out.stdout


def main():
    rows = run()
    print("algo,alpha,selected,CR,time_s,obj,converged")
    for r in rows:
        print(f"{r['algo']},{r['alpha']},{r['selected']},{r['cr']},"
              f"{r['time_s']:.3f},{r['obj']:.6f},{r['converged']}")
    # paper Fig. 3: for k0 = 10 the CR needed to converge is only weakly
    # alpha-dependent for FedGiA
    crs = [r["cr"] for r in rows if r["algo"] == "fedgia_d" and r["converged"]]
    if len(crs) >= 2:
        assert max(crs) <= 3 * min(crs), f"alpha swung FedGiA CR too much: {crs}"
    print("\n-- sharded path (8 fake devices) --")
    print(run_sharded())
    return rows


if __name__ == "__main__":
    main()
