"""Wall-clock benchmark: time-to-target-accuracy vs straggler severity.

The paper's cost accounting (§V, Table 4) argues FedGiA wins on
COMMUNICATION rounds; this benchmark asks the time question the async
engine + wall-clock simulation (core/clock.py) make answerable: when the
fleet is heterogeneous — the slowest client `spread`x slower than the
fastest — how much SIMULATED wall-clock does each algorithm need to reach
the paper's stopping rule, and how much of the damage does
staleness-aware aggregation (`stale_weighting="poly"`) undo?

Per (algorithm, spread, weighting) the sweep runs clock-driven async
rounds (constant per-client speeds geometrically spaced from 1s to
`spread`s, staleness bounded at MAX_STALENESS) and reports the rounds to
target (CR), the simulated seconds to target (`sim_time` at the stopping
round — the event-driven server's actual time axis) and the staleness
actually used. spread=1 is the homogeneous-fleet reference: every client
arrives every round, so it coincides with the synchronous engine and
anchors the degradation curves.

The sweep is DETERMINISTIC (simulated time, fixed seeds): CR, sim_time
and objectives are machine-independent, so main() can assert the shape
of the curves, not just invariants. Two standing read-outs: (a) at equal
spread FedGiA needs far fewer rounds to target than SCAFFOLD/FedAvg —
the paper's CR edge survives the straggler regime; (b) staleness
weighting helps the MODEL-AVERAGING baselines slightly but hurts
FedGiA: eq. (11) is a consensus mean whose uniform weights cancel the
dual mean (Σπ_i/m ≈ 0), and any reweighting re-introduces a dual bias
of order decay·std(π) — which is why "uniform" is the default
(docs/async.md discusses this).

`main()` writes BENCH_wallclock.json (path: WALLCLOCK_BENCH_JSON) and
returns the rows for benchmarks/run.py. Env knobs for CI budgets:
WALLCLOCK_MAX_ROUNDS (default 400).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import M_CLIENTS, make_problem
from repro.config import FedConfig
from repro.core import make_algorithm, run_rounds
from repro.core.clock import ComputeClock

MAX_ROUNDS = int(os.environ.get("WALLCLOCK_MAX_ROUNDS", "400"))
JSON_PATH = os.environ.get("WALLCLOCK_BENCH_JSON", "BENCH_wallclock.json")
K0 = 10
MAX_STALENESS = 4
SPREADS = [1.0, 4.0, 16.0]
WEIGHTINGS = ["uniform", "poly"]
ALGOS = {
    "fedgia_d": dict(algorithm="fedgia", sigma_t=0.15, h_policy="diag_ema",
                     alpha=1.0),  # branch split = the arrival mask
    "scaffold": dict(algorithm="scaffold", lr=0.01),
    "fedavg": dict(algorithm="fedavg", lr=0.01),
}


def straggler_speeds(m: int, spread: float) -> np.ndarray:
    """Per-client compute seconds geometrically spaced in [1, spread]:
    the severity knob is the slow/fast ratio, the median stays put."""
    if spread <= 1.0:
        return np.ones(m, np.float32)
    return spread ** (np.arange(m, dtype=np.float32) / (m - 1))


def run():
    rows = []
    model, batch, tol = make_problem("linreg", 0)
    for algo_key, hp in ALGOS.items():
        fed = FedConfig(num_clients=M_CLIENTS, k0=K0, **hp)
        algo = make_algorithm(fed, model.loss, model=model)
        state = algo.init(model.init(jax.random.PRNGKey(0)),
                          jax.random.PRNGKey(1), init_batch=batch)
        for spread in SPREADS:
            clk = ComputeClock(M_CLIENTS, straggler_speeds(M_CLIENTS, spread))
            for weighting in WEIGHTINGS:
                res = run_rounds(algo, state, batch, MAX_ROUNDS, tol=tol,
                                 clock=clk, max_staleness=MAX_STALENESS,
                                 stale_weighting=weighting)
                rows.append({
                    "algo": algo_key,
                    "spread": spread,
                    "weighting": weighting,
                    "cr": 2 * res.rounds_run,
                    "sim_time_s": float(res.history["sim_time"][-1]),
                    "staleness_seen": int(res.history["staleness_max"].max()),
                    "obj": float(res.history["f_xbar"][-1]),
                    "converged": res.stopped_early,
                })
    return rows


def main():
    rows = run()
    print("algo,spread,weighting,CR,sim_time_s,staleness_seen,obj,converged")
    for r in rows:
        print(f"{r['algo']},{r['spread']:g},{r['weighting']},{r['cr']},"
              f"{r['sim_time_s']:.2f},{r['staleness_seen']},"
              f"{r['obj']:.6f},{r['converged']}")
    # invariants the sweep must satisfy regardless of hardware: bounded
    # staleness, and a homogeneous fleet (spread=1, everyone fresh after
    # the one-round pipeline delay) identical across weightings — the
    # weights only differ where staleness differs across clients
    for r in rows:
        assert r["staleness_seen"] <= MAX_STALENESS, r
    by_key = {(r["algo"], r["spread"], r["weighting"]): r for r in rows}
    for algo_key in ALGOS:
        u = by_key[(algo_key, 1.0, "uniform")]
        assert u["staleness_seen"] <= 1, u  # homogeneous: pipeline delay only
    if MAX_ROUNDS >= 400:
        # deterministic sweep: FedGiA under uniform weighting reaches the
        # paper's stopping rule at EVERY straggler severity (the CR edge
        # over the baselines survives the event-driven regime)
        for spread in SPREADS:
            assert by_key[("fedgia_d", spread, "uniform")]["converged"], (
                by_key[("fedgia_d", spread, "uniform")])
    out = {
        "max_rounds": MAX_ROUNDS,
        "clients": M_CLIENTS,
        "k0": K0,
        "max_staleness": MAX_STALENESS,
        "rows": rows,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"wrote {JSON_PATH} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    main()
