"""Wall-clock benchmark: time-to-target-accuracy vs straggler severity.

The paper's cost accounting (§V, Table 4) argues FedGiA wins on
COMMUNICATION rounds; this benchmark asks the time question the async
engine + wall-clock simulation (core/clock.py) make answerable: when the
fleet is heterogeneous — the slowest client `spread`x slower than the
fastest — how much SIMULATED wall-clock does each algorithm need to reach
the paper's stopping rule, and how much of the damage does
staleness-aware aggregation (`stale_weighting="poly"`) undo?

Per (algorithm, spread, weighting) the sweep runs clock-driven async
rounds (constant per-client speeds geometrically spaced from 1s to
`spread`s, staleness bounded at MAX_STALENESS) and reports the rounds to
target (CR), the simulated seconds to target (`sim_time` at the stopping
round — the event-driven server's actual time axis) and the staleness
actually used. spread=1 is the homogeneous-fleet reference: every client
arrives every round, so it coincides with the synchronous engine and
anchors the degradation curves.

The sweep is DETERMINISTIC (simulated time, fixed seeds): CR, sim_time
and objectives are machine-independent, so main() can assert the shape
of the curves, not just invariants. Two standing read-outs: (a) at equal
spread FedGiA needs far fewer rounds to target than SCAFFOLD/FedAvg —
the paper's CR edge survives the straggler regime; (b) staleness
weighting helps the MODEL-AVERAGING baselines slightly but hurts
FedGiA: eq. (11) is a consensus mean whose uniform weights cancel the
dual mean (Σπ_i/m ≈ 0), and any reweighting re-introduces a dual bias
of order decay·std(π) — which is why "uniform" is the default
(docs/async.md discusses this).

The COMPRESSION section asks the follow-up question the byte-accurate
clock (PR-7, `bandwidth_bps=`) makes answerable: with the wire priced in
bytes — uplink through the codec, fp32 downlink — does compressing
eq. (11)'s uplink buy TIME-TO-TARGET, not just fewer bits? A homogeneous
fleet with a small compute share (COMPRESS_COMPUTE_S) and a constrained
link (BANDWIDTH_BPS, wire-dominated rounds) runs FedGiA under each codec
(`none` / `bf16` / `int8`+EF / `topk`+EF); rows carry a `codec` field and
the distinct algo name `fedgia_d_bw` so the gate keys stay unique.
main() asserts at least one lossy codec beats `none` on sim_time — the
codec's extra rounds (if any) must cost less than the bytes it saves.

The OVERLAP section prices the eq.-(11)-behind-compute claim
(docs/engine.md#overlapped-collectives): the same wire-dominated regime
run twice, `overlap="off"` (barrier pricing — compute then wire, in
series) vs `overlap="scatter"`, under which the engine installs
``clock.with_overlap()`` and each round costs ``max(compute, comm)``
instead of their sum — i.e. the round is credited ``min(compute_s,
comm_s)`` of hidden latency. Rows carry the distinct algo names
`fedgia_d_ovl_off` / `fedgia_d_ovl_on` so the check_bench gate keys
stay unique; main() asserts the scatter row reaches the target in
strictly less simulated time than the barrier row.

The FAULTS section prices robustness (docs/faults.md): the spread=4
straggler regime re-run under an on-device fault campaign — crash+nan
at FAULT_RATE per kind, defended by the screening stage riding
eq. (11)'s collective plus a quorum floor. Faults + screening reduce
to extra (adversarially chosen, but screened) non-participation, so
the run must STILL reach the converged loss level — just later: the
`fedgia_d_faulty` row records the simulated time the campaign costs
over the clean `fedgia_d_faultref` row (identical clock + loss
target, no faults), and the gate pins both. The draw is stateless
per-round, so the rows are exactly as deterministic as the clean
ones.

`main()` writes BENCH_wallclock.json (path: WALLCLOCK_BENCH_JSON) and
returns the rows for benchmarks/run.py. Env knobs for CI budgets:
WALLCLOCK_MAX_ROUNDS (default 400).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import M_CLIENTS, make_problem
from repro.config import FedConfig
from repro.core import make_algorithm, run_rounds
from repro.core.clock import ComputeClock

MAX_ROUNDS = int(os.environ.get("WALLCLOCK_MAX_ROUNDS", "400"))
JSON_PATH = os.environ.get("WALLCLOCK_BENCH_JSON", "BENCH_wallclock.json")
K0 = 10
MAX_STALENESS = 4
SPREADS = [1.0, 4.0, 16.0]
WEIGHTINGS = ["uniform", "poly"]
ALGOS = {
    "fedgia_d": dict(algorithm="fedgia", sigma_t=0.15, h_policy="diag_ema",
                     alpha=1.0),  # branch split = the arrival mask
    "scaffold": dict(algorithm="scaffold", lr=0.01),
    "fedavg": dict(algorithm="fedavg", lr=0.01),
}

# Compression section: a wire-dominated regime. At n=100 the raw fp32
# round moves 408 B up + 408 B down per client — ~0.2 s at BANDWIDTH_BPS
# against 0.05 s of compute, so codec savings translate almost 1:1 into
# round duration. The target is a LOSS level, not the paper's gradient
# rule: lossy codecs orbit a quantization noise floor that keeps
# grad_sq_norm above eq. (35)'s tol forever, while f(x̄) still reaches
# the converged objective (~0.00492 on this problem) to within a few
# percent. 0.0052 sits above every convergent codec's floor (int8+EF
# floors at ~0.00515) and none of the divergent ones (top-k
# sparsification of FedGiA's dense consensus z-uploads diverges here
# even WITH error feedback — the row records that honestly).
COMPRESS_COMPUTE_S = 0.05
BANDWIDTH_BPS = 4000.0  # bytes/s per client link
COMPRESS_TARGET_F = 0.0052

# Faults section: per-kind injection rate for the crash+nan campaign and
# the screening clip. 0.1 per kind leaves the quorum comfortably met in
# every round (m=128) while injecting enough non-arrival that the time
# cost over the clean row is visible and gate-worthy.
FAULT_KINDS = ["crash", "nan"]
FAULT_RATE = 0.1
FAULT_CLIP = 100.0
FAULT_QUORUM = 2
FAULT_SPREAD = 4.0
CODECS = [
    ("none", dict(compression="none")),
    ("bf16", dict(compression="bf16")),
    ("int8", dict(compression="int8", error_feedback=True)),
    ("topk", dict(compression="topk", topk_frac=0.25, error_feedback=True)),
]


def straggler_speeds(m: int, spread: float) -> np.ndarray:
    """Per-client compute seconds geometrically spaced in [1, spread]:
    the severity knob is the slow/fast ratio, the median stays put."""
    if spread <= 1.0:
        return np.ones(m, np.float32)
    return spread ** (np.arange(m, dtype=np.float32) / (m - 1))


def run():
    rows = []
    model, batch, tol = make_problem("linreg", 0)
    for algo_key, hp in ALGOS.items():
        fed = FedConfig(num_clients=M_CLIENTS, k0=K0, **hp)
        algo = make_algorithm(fed, model.loss, model=model)
        state = algo.init(model.init(jax.random.PRNGKey(0)),
                          jax.random.PRNGKey(1), init_batch=batch)
        for spread in SPREADS:
            clk = ComputeClock(M_CLIENTS, straggler_speeds(M_CLIENTS, spread))
            for weighting in WEIGHTINGS:
                res = run_rounds(algo, state, batch, MAX_ROUNDS, tol=tol,
                                 clock=clk, max_staleness=MAX_STALENESS,
                                 stale_weighting=weighting)
                rows.append({
                    "algo": algo_key,
                    "spread": spread,
                    "weighting": weighting,
                    "cr": 2 * res.rounds_run,
                    "sim_time_s": float(res.history["sim_time"][-1]),
                    "staleness_seen": int(res.history["staleness_max"].max()),
                    "obj": float(res.history["f_xbar"][-1]),
                    "converged": res.stopped_early,
                })
    return rows


def run_compression():
    """Time-to-target per codec under the byte-accurate clock (the
    uplink priced by the codec's exact wire size, fp32 downlink);
    target = f(x̄) <= COMPRESS_TARGET_F, see the constant's comment."""
    rows = []
    model, batch, _ = make_problem("linreg", 0)
    fed = FedConfig(num_clients=M_CLIENTS, k0=K0, **ALGOS["fedgia_d"])
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    for codec, kw in CODECS:
        clk = ComputeClock(M_CLIENTS, compute_s=COMPRESS_COMPUTE_S,
                           bandwidth_bps=BANDWIDTH_BPS)
        res = run_rounds(algo, state, batch, MAX_ROUNDS,
                         tol=COMPRESS_TARGET_F, tol_metric="f_xbar",
                         clock=clk, max_staleness=MAX_STALENESS,
                         stale_weighting="uniform", **kw)
        rows.append({
            "algo": "fedgia_d_bw",
            "spread": 1.0,
            "weighting": "uniform",
            "codec": codec,
            "cr": 2 * res.rounds_run,
            "sim_time_s": float(res.history["sim_time"][-1]),
            "bytes_up_total": float(np.sum(res.history["bytes_up"])),
            "bytes_down_total": float(np.sum(res.history["bytes_down"])),
            "staleness_seen": int(res.history["staleness_max"].max()),
            "obj": float(res.history["f_xbar"][-1]),
            "converged": res.stopped_early,
        })
    return rows


def run_overlap():
    """Time-to-target with eq. (11) hidden behind compute: the
    compression section's wire-dominated regime (raw fp32 codec), run
    with `overlap="off"` — barrier pricing, compute and wire in series
    — and with `overlap="scatter"`, under which the engine installs
    ``clock.with_overlap()`` and each round costs ``max(compute, comm)``
    — crediting ``min(compute_s, comm_s)`` of hidden latency per round.
    The trajectories agree to fp tolerance (tests/test_overlap.py), so
    any sim_time gap is pure latency hiding, not an algorithmic edge."""
    rows = []
    model, batch, _ = make_problem("linreg", 0)
    fed = FedConfig(num_clients=M_CLIENTS, k0=K0, **ALGOS["fedgia_d"])
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    for algo_key, overlap in (("fedgia_d_ovl_off", "off"),
                              ("fedgia_d_ovl_on", "scatter")):
        clk = ComputeClock(M_CLIENTS, compute_s=COMPRESS_COMPUTE_S,
                           bandwidth_bps=BANDWIDTH_BPS)
        res = run_rounds(algo, state, batch, MAX_ROUNDS,
                         tol=COMPRESS_TARGET_F, tol_metric="f_xbar",
                         clock=clk, max_staleness=MAX_STALENESS,
                         stale_weighting="uniform", overlap=overlap)
        rows.append({
            "algo": algo_key,
            "spread": 1.0,
            "weighting": "uniform",
            "codec": "none",
            "overlap": overlap,
            "cr": 2 * res.rounds_run,
            "sim_time_s": float(res.history["sim_time"][-1]),
            "bytes_up_total": float(np.sum(res.history["bytes_up"])),
            "bytes_down_total": float(np.sum(res.history["bytes_down"])),
            "staleness_seen": int(res.history["staleness_max"].max()),
            "obj": float(res.history["f_xbar"][-1]),
            "converged": res.stopped_early,
        })
    return rows


def run_faults():
    """Time-to-target under an on-device crash+nan campaign with the
    screening defense and a quorum floor (docs/faults.md), in the
    spread=FAULT_SPREAD straggler regime. The target is the loss level
    COMPRESS_TARGET_F, not eq. (35)'s gradient rule: the campaign
    injects fresh non-arrival every round, so the stale gradient
    surrogate orbits an injection noise floor that keeps grad_sq_norm
    above tol long after f(x̄) has converged — the same reasoning as
    the codec floors. A clean reference row (`fedgia_d_faultref`) runs
    the identical clock + target with no faults, so the gap between
    the two rows is exactly the simulated time the campaign costs."""
    from repro.core import Screening, make_faults

    rows = []
    model, batch, _ = make_problem("linreg", 0)
    fed = FedConfig(num_clients=M_CLIENTS, k0=K0, **ALGOS["fedgia_d"])
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    campaign = dict(faults=make_faults(FAULT_KINDS, [FAULT_RATE],
                                       num_clients=M_CLIENTS, seed=0),
                    screening=Screening(clip_norm=FAULT_CLIP),
                    quorum=FAULT_QUORUM)
    for algo_key, kw in (("fedgia_d_faultref", {}),
                         ("fedgia_d_faulty", campaign)):
        clk = ComputeClock(M_CLIENTS,
                           straggler_speeds(M_CLIENTS, FAULT_SPREAD))
        res = run_rounds(algo, state, batch, MAX_ROUNDS,
                         tol=COMPRESS_TARGET_F, tol_metric="f_xbar",
                         clock=clk, max_staleness=MAX_STALENESS,
                         stale_weighting="uniform", **kw)
        row = {
            "algo": algo_key,
            "spread": FAULT_SPREAD,
            "weighting": "uniform",
            "codec": "none",
            "cr": 2 * res.rounds_run,
            "sim_time_s": float(res.history["sim_time"][-1]),
            "staleness_seen": int(res.history["staleness_max"].max()),
            "obj": float(res.history["f_xbar"][-1]),
            "converged": res.stopped_early,
        }
        if kw:
            row.update({
                "faults": ",".join(FAULT_KINDS),
                "fault_rate": FAULT_RATE,
                "screened_min": int(res.history["screened"].min()),
                "degraded_rounds": int(res.history["degraded"].sum()),
            })
        rows.append(row)
    return rows


def main():
    rows = run() + run_compression() + run_overlap() + run_faults()
    print("algo,spread,weighting,codec,CR,sim_time_s,staleness_seen,obj,"
          "converged")
    for r in rows:
        print(f"{r['algo']},{r['spread']:g},{r['weighting']},"
              f"{r.get('codec', 'none')},{r['cr']},"
              f"{r['sim_time_s']:.2f},{r['staleness_seen']},"
              f"{r['obj']:.6f},{r['converged']}")
    # invariants the sweep must satisfy regardless of hardware: bounded
    # staleness, and a homogeneous fleet (spread=1, everyone fresh after
    # the one-round pipeline delay) identical across weightings — the
    # weights only differ where staleness differs across clients
    for r in rows:
        assert r["staleness_seen"] <= MAX_STALENESS, r
    by_key = {(r["algo"], r["spread"], r["weighting"],
               r.get("codec", "none")): r for r in rows}
    for algo_key in ALGOS:
        u = by_key[(algo_key, 1.0, "uniform", "none")]
        assert u["staleness_seen"] <= 1, u  # homogeneous: pipeline delay only
    if MAX_ROUNDS >= 400:
        # deterministic sweep: FedGiA under uniform weighting reaches the
        # paper's stopping rule at EVERY straggler severity (the CR edge
        # over the baselines survives the event-driven regime)
        for spread in SPREADS:
            assert by_key[("fedgia_d", spread, "uniform", "none")][
                "converged"], by_key[("fedgia_d", spread, "uniform", "none")]
        # byte-accurate clock: at least one lossy codec converts its wire
        # savings into strictly less simulated time-to-target than the
        # uncompressed round (fewer bits AND less time, the PR-7 claim)
        raw = by_key[("fedgia_d_bw", 1.0, "uniform", "none")]
        assert raw["converged"], raw
        lossy = [by_key[("fedgia_d_bw", 1.0, "uniform", c)]
                 for c, _ in CODECS if c != "none"]
        assert any(r["converged"] and r["sim_time_s"] < raw["sim_time_s"]
                   for r in lossy), (raw, lossy)
        # overlapped collectives: hiding eq. (11) behind compute must buy
        # strictly less simulated time-to-target than the barrier round —
        # the trajectories agree to fp tolerance, so the gap is latency
        ovl_off = by_key[("fedgia_d_ovl_off", 1.0, "uniform", "none")]
        ovl_on = by_key[("fedgia_d_ovl_on", 1.0, "uniform", "none")]
        assert ovl_off["converged"] and ovl_on["converged"], (ovl_off, ovl_on)
        assert ovl_on["sim_time_s"] < ovl_off["sim_time_s"], (ovl_off, ovl_on)
        # fault campaign: screened crash+nan injection still reaches the
        # paper's stopping rule, the quorum floor is never even close
        # (screened >= quorum every round), and the robustness toll is
        # pure extra rounds — more sim time than the clean row under the
        # identical clock, never divergence
        faulty = by_key[("fedgia_d_faulty", FAULT_SPREAD, "uniform", "none")]
        clean = by_key[("fedgia_d_faultref", FAULT_SPREAD, "uniform",
                        "none")]
        assert faulty["converged"] and clean["converged"], (faulty, clean)
        assert faulty["screened_min"] >= FAULT_QUORUM, faulty
        assert faulty["degraded_rounds"] == 0, faulty
        assert faulty["sim_time_s"] > clean["sim_time_s"], (faulty, clean)
    out = {
        "max_rounds": MAX_ROUNDS,
        "clients": M_CLIENTS,
        "k0": K0,
        "max_staleness": MAX_STALENESS,
        "rows": rows,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"wrote {JSON_PATH} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    main()
