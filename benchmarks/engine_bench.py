"""Round-engine benchmark: scan-compiled chunks vs the seed's per-round
dispatch loop — plus the client-sharded and async (stale-x̄) engine paths,
and the flat-buffer round path against its per-leaf pytree twin — on the
paper's linreg problem, fixed round count (no early stop) so every path
executes comparable math.

The legacy path pays one dispatch + one metric host-sync per round; the
scan path pays one dispatch per chunk and no per-round syncs. On CPU with
the paper-scale problem the speedup is dominated by removed dispatch
latency — exactly the overhead that grows with round count. The sharded
path runs in a subprocess over 8 FAKE CPU devices (so its round/s is a
plumbing sanity number, not a hardware claim); `scan_overlap` is the
same sharded run with `overlap="scatter"` (eq. (11) split into an early
reduce-scatter plus a deferred consensus all-gather), so its row pins
that the carry-slot bookkeeping costs ~nothing on one socket; the async
path adds the staleness carry + per-client anchor selects to the scan
path, and its round/s shows that overlap bookkeeping is (near) free.

`scan` is the shipping configuration (flat=True: ravel-once (m, N) client
state, contiguous eq.-11 reduction, fused branch update);
`scan_pytree` is the same scan engine with `flat=False`. The two are
measured INTERLEAVED (scan/pytree/scan/pytree/...) so slow drift on a
shared CI runner hits both paths equally; the reported ratio is the
ratio of the two per-path medians. On the single-leaf linreg model the flat win is
moderate (ravel is a no-op reshape, the gain is fewer HLO ops per round);
multi-leaf models widen it.

`active_1m` is the active-set store at the regime the dense store cannot
represent: m = 10^6 clients, alpha = 10^-4 (100 participants per round,
FedAvg — the frozen-client family the store accelerates). The round's
trajectories and gradient evaluations are (100, N) tiles instead of
(10^6, N) buffers; what remains O(m) per round is the mask draw and the
one streaming eq.-11 reduction (scattered back to the dense layout so
results stay bitwise the dense store's — api.flat_round_aggregate_active).
The batch is built directly (one sample per client) because the paper's
heterogeneous-size splitter is O(m^2) at this scale.

`offload_1m` is the host-offloaded store at the same scale, on the
algorithm the offload exists for: FedPD carries a RESIDENT (m, N) dual
buffer, so at m = 10^6 the client state alone is ~0.5 GB — dense OR
active, that buffer lives on the device; `store="offload"` moves it (and
the resident batch) to pinned host memory and shuttles (100, N) tiles
per round. With `aggregate="packed"` the eq.-11 reduction sums the tile
directly, so NOTHING O(m) is resident on the device — the row reports
the compiled tile round's peak device bytes (XLA memory_analysis, None
where the backend doesn't report it) next to the analytic dense-store
footprint it displaced.

`run()` returns the machine-readable dict that `benchmarks/run.py` dumps
to BENCH_engine.json (round/s per path). Env knobs for CI budgets:
ENGINE_BENCH_ROUNDS (default 200), ENGINE_BENCH_REPEATS (default 3),
ENGINE_BENCH_1M_ROUNDS (default 3), ENGINE_BENCH_1M_CLIENTS (default
1_000_000 — shrink for smoke runs).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import M_CLIENTS, make_problem
from repro.config import FedConfig
from repro.core import make_algorithm, run_rounds
from repro.core.selection import AvailabilityParticipation

ROUNDS = int(os.environ.get("ENGINE_BENCH_ROUNDS", "200"))
REPEATS = int(os.environ.get("ENGINE_BENCH_REPEATS", "3"))
ROUNDS_1M = int(os.environ.get("ENGINE_BENCH_1M_ROUNDS", "3"))
M_1M = int(os.environ.get("ENGINE_BENCH_1M_CLIENTS", "1000000"))
ALPHA_1M = 1e-4

_SHARDED_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from benchmarks.common import M_CLIENTS, make_problem
    from repro.config import FedConfig
    from repro.core import make_algorithm, run_rounds
    from repro.launch.mesh import make_host_mesh

    ROUNDS = {rounds}
    model, batch, _ = make_problem("linreg", 0)
    fed = FedConfig(algorithm="fedgia", num_clients=M_CLIENTS, k0=5,
                    alpha=0.5, sigma_t=0.15, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    mesh = make_host_mesh(data=8)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, mesh=mesh,
                     overlap="{overlap}")
    print(f"SHARDED_WALL_S={{res.wall_s:.6f}}")
    """
)


def _measure(fn):
    walls = []
    for _ in range(REPEATS):
        walls.append(fn().wall_s)
    return float(np.median(walls))


def run():
    model, batch, _ = make_problem("linreg", 0)
    fed = FedConfig(algorithm="fedgia", num_clients=M_CLIENTS, k0=5,
                    alpha=0.5, sigma_t=0.15, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)

    res_loop = res_scan = res_pytree = res_async = None

    def loop():
        nonlocal res_loop
        res_loop = run_rounds(algo, state, batch, ROUNDS, scan=False)
        return res_loop

    def scan():
        nonlocal res_scan
        res_scan = run_rounds(algo, state, batch, ROUNDS, scan=True)
        return res_scan

    def scan_pytree():
        nonlocal res_pytree
        res_pytree = run_rounds(algo, state, batch, ROUNDS, scan=True,
                                flat=False)
        return res_pytree

    # async: heterogeneous periodic arrivals, bounded staleness 2. alpha is
    # irrelevant (the arrival mask IS the branch split).
    pol = AvailabilityParticipation.from_periods(
        M_CLIENTS, 1 + (np.arange(M_CLIENTS) % 4), horizon=ROUNDS)

    def asyn():
        nonlocal res_async
        res_async = run_rounds(algo, state, batch, ROUNDS, scan=True,
                               participation=pol, async_rounds=True,
                               max_staleness=2)
        return res_async

    loop_s, async_s = _measure(loop), _measure(asyn)
    # flat vs pytree scan: interleaved repeats so runner drift hits both
    # paths equally; per-path median
    flat_walls, pytree_walls = [], []
    for _ in range(REPEATS):
        flat_walls.append(scan().wall_s)
        pytree_walls.append(scan_pytree().wall_s)
    scan_s = float(np.median(flat_walls))
    pytree_s = float(np.median(pytree_walls))
    # the sync paths must agree before their times are comparable (flat is
    # bitwise the pytree scan on a single device — tests/test_flat.py)
    for k in ("f_xbar", "grad_sq_norm"):
        np.testing.assert_allclose(res_scan.history[k], res_loop.history[k],
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(res_scan.history[k],
                                      res_pytree.history[k])
    assert int(res_async.history["staleness_max"].max()) <= 2

    sharded_s = run_sharded()
    sharded_overlap_s = run_sharded(overlap="scatter")
    active_1m = run_active_1m()
    offload_1m = run_offload_1m()
    r = {
        "rounds": ROUNDS,
        "clients": M_CLIENTS,
        "paths": {
            "legacy": {"wall_s": loop_s, "rounds_per_s": ROUNDS / loop_s},
            "scan": {"wall_s": scan_s, "rounds_per_s": ROUNDS / scan_s,
                     "note": "flat-buffer rounds (the default path)"},
            "scan_pytree": {"wall_s": pytree_s,
                            "rounds_per_s": ROUNDS / pytree_s,
                            "note": "per-leaf pytree rounds (--no-flat)"},
            "sharded": {"wall_s": sharded_s,
                        "rounds_per_s": ROUNDS / sharded_s,
                        "note": "8 fake CPU devices, one physical socket"},
            # overlap on 8 FAKE devices shares one socket, so round/s is a
            # no-extra-overhead sanity number; the latency win is priced by
            # the wall-clock bench's byte clock (min(compute, comm) credit)
            "scan_overlap": {"wall_s": sharded_overlap_s,
                             "rounds_per_s": ROUNDS / sharded_overlap_s,
                             "note": "sharded scan, overlap='scatter' "
                                     "(early RS + deferred consensus AG)"},
            "async": {"wall_s": async_s, "rounds_per_s": ROUNDS / async_s,
                      "max_staleness": 2},
            "active_1m": active_1m,
            "offload_1m": offload_1m,
        },
        "speedup_scan_vs_legacy": loop_s / scan_s,
        "speedup_flat_vs_pytree": pytree_s / scan_s,
        # NOTE: not a pure bookkeeping-overhead ratio — stale rounds
        # evaluate gradients at PER-CLIENT anchors (a batched dot), which
        # CPU XLA parallelizes differently from the sync path's
        # shared-params evaluation; on CPU the async path is routinely
        # FASTER. The staleness carry itself adds only elementwise selects.
        "overhead_async_vs_scan": async_s / scan_s,
    }
    return r


def run_active_1m() -> dict:
    """Million-client active-store rounds: FedAvg, m=M_1M, alpha=1e-4.

    Uses the `LeastSquares` model on a directly-built one-sample-per-
    client batch (n=32 features; the resident batch is the only (m, ...)
    input). Dense has no twin row here — its per-round working set would
    be k0 (m, N) trajectory buffers plus m gradient evaluations."""
    from repro.core import make_policy
    from repro.models import LeastSquares

    n = 32
    rng = np.random.default_rng(0)
    A = rng.standard_normal((M_1M, 1, n)).astype(np.float32)
    x_star = rng.standard_normal(n).astype(np.float32)
    b = (A @ x_star + 0.1 * rng.standard_normal((M_1M, 1))).astype(np.float32)
    batch = {"A": jnp.asarray(A), "b": jnp.asarray(b),
             "mask": jnp.ones((M_1M, 1), jnp.float32)}
    model = LeastSquares(n)
    fed = FedConfig(algorithm="fedavg", num_clients=M_1M, k0=5, lr=0.01)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    pol = make_policy("uniform", M_1M, ALPHA_1M, seed=0)
    res = run_rounds(algo, state, batch, ROUNDS_1M, participation=pol,
                     store="active")
    assert res.rounds_run == ROUNDS_1M
    assert int(res.history["selected"][0]) == pol.n_selected
    return {
        "wall_s": res.wall_s,
        "rounds_per_s": ROUNDS_1M / res.wall_s,
        "clients": M_1M,
        "alpha": ALPHA_1M,
        "participants_per_round": pol.n_selected,
        "rounds": ROUNDS_1M,
        "note": "active-set store, FedAvg: (|C|, N) tile rounds at m=1e6",
    }


def run_offload_1m() -> dict:
    """Million-client host-offloaded rounds: FedPD, m=M_1M, alpha=1e-4,
    store="offload" + aggregate="packed".

    FedPD is the demonstration because its dual variable λᵢ is a
    resident (m, N) client buffer — the thing the offload store exists
    to move off the device. The row carries the measured device/host
    split next to the analytic dense footprint it displaced."""
    from repro.core import make_policy
    from repro.models import LeastSquares

    n = 32
    rng = np.random.default_rng(0)
    A = rng.standard_normal((M_1M, 1, n)).astype(np.float32)
    x_star = rng.standard_normal(n).astype(np.float32)
    b = (A @ x_star + 0.1 * rng.standard_normal((M_1M, 1))).astype(np.float32)
    batch = {"A": jnp.asarray(A), "b": jnp.asarray(b),
             "mask": jnp.ones((M_1M, 1), jnp.float32)}
    model = LeastSquares(n)
    fed = FedConfig(algorithm="fedpd", num_clients=M_1M, k0=5, lr=0.05,
                    fedpd_eta=1.0)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    pol = make_policy("uniform", M_1M, ALPHA_1M, seed=0)
    res = run_rounds(algo, state, batch, ROUNDS_1M, participation=pol,
                     store="offload", aggregate="packed")
    assert res.rounds_run == ROUNDS_1M
    assert int(res.history["selected"][0]) == pol.n_selected
    # the dense (or active) store would keep λ resident ON DEVICE: one
    # (m, N) flat buffer (N = lane-padded model size)
    from repro.utils import pytree as pt
    spec = pt.ravel_spec(state["x"])
    dense_resident = M_1M * spec.padded_size * np.dtype(spec.dtype).itemsize
    peak = res.extras.get("device_peak_bytes")
    # the fixed per-round overhead (mask, ids, metric stack) only
    # amortizes at real scale — skip the footprint assert on shrunk
    # ENGINE_BENCH_1M_CLIENTS smoke runs
    if peak is not None and M_1M >= 100_000:
        assert peak < dense_resident, (
            f"offload tile round peaks at {peak}B on device — not below "
            f"the {dense_resident}B dense-store λ buffer it displaced")
    return {
        "wall_s": res.wall_s,
        "rounds_per_s": ROUNDS_1M / res.wall_s,
        "clients": M_1M,
        "alpha": ALPHA_1M,
        "participants_per_round": pol.n_selected,
        "rounds": ROUNDS_1M,
        "peak_device_bytes": peak,
        "host_resident_bytes": res.extras.get("host_resident_bytes"),
        "dense_resident_bytes": dense_resident,
        "note": "host-offloaded store + packed eq. (11), FedPD: resident "
                "(m, N) duals in host memory, (|C|, N) tiles on device",
    }


def run_sharded(overlap: str = "off") -> float:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c",
         _SHARDED_SCRIPT.format(rounds=ROUNDS, overlap=overlap)],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    m = re.search(r"SHARDED_WALL_S=([\d.]+)", out.stdout)
    assert m, out.stdout + out.stderr
    return float(m.group(1))


def main():
    r = run()
    print("path,wall_s,rounds_per_s")
    for name, p in r["paths"].items():
        print(f"{name},{p['wall_s']:.3f},{p['rounds_per_s']:.1f}")
    print(f"speedup scan vs legacy: {r['speedup_scan_vs_legacy']:.2f}x, "
          f"flat vs pytree: {r['speedup_flat_vs_pytree']:.2f}x, "
          f"async overhead vs scan: {r['overhead_async_vs_scan']:.2f}x")
    assert r["speedup_scan_vs_legacy"] > 1.0, (
        f"scan engine slower than per-round dispatch: {r}")
    # interleaved medians: the flat round path must not lose to its pytree
    # twin (2% grace for shared-runner noise; the check_bench gate pins
    # the absolute round/s trajectory)
    assert r["speedup_flat_vs_pytree"] >= 0.98, (
        f"flat rounds slower than pytree rounds: {r}")
    return r


if __name__ == "__main__":
    main()
