"""Round-engine benchmark: scan-compiled chunks vs the seed's per-round
dispatch loop, on the paper's linreg problem, >= 100 rounds, fixed length
(no early stop) so both paths execute identical math.

The legacy path pays one dispatch + one metric host-sync per round; the
scan path pays one dispatch per chunk and no per-round syncs. On CPU with
the paper-scale problem the speedup is dominated by removed dispatch
latency — exactly the overhead that grows with round count.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import M_CLIENTS, make_problem
from repro.config import FedConfig
from repro.core import make_algorithm, run_rounds

ROUNDS = 200
REPEATS = 3


def run():
    model, batch, _ = make_problem("linreg", 0)
    fed = FedConfig(algorithm="fedgia", num_clients=M_CLIENTS, k0=5,
                    alpha=0.5, sigma_t=0.15, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)

    loop_t, scan_t = [], []
    for _ in range(REPEATS):
        res_loop = run_rounds(algo, state, batch, ROUNDS, scan=False)
        res_scan = run_rounds(algo, state, batch, ROUNDS, scan=True)
        loop_t.append(res_loop.wall_s)
        scan_t.append(res_scan.wall_s)
    # the two paths must agree before their times are comparable
    for k in ("f_xbar", "grad_sq_norm"):
        np.testing.assert_allclose(res_scan.history[k], res_loop.history[k],
                                   rtol=1e-5, atol=1e-6)
    return {
        "rounds": ROUNDS,
        "loop_s": float(np.median(loop_t)),
        "scan_s": float(np.median(scan_t)),
        "speedup": float(np.median(loop_t) / np.median(scan_t)),
    }


def main():
    r = run()
    print("rounds,legacy_loop_s,scan_engine_s,speedup")
    print(f"{r['rounds']},{r['loop_s']:.3f},{r['scan_s']:.3f},"
          f"{r['speedup']:.2f}x")
    assert r["speedup"] > 1.0, (
        f"scan engine slower than per-round dispatch: {r}")
    return r


if __name__ == "__main__":
    main()
