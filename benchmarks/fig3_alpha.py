"""Paper Fig. 3: effect of the selection fraction alpha — little CR impact
for k0 > 5; FedGiA_D time roughly flat in alpha.

alpha is applied through the ENGINE's uniform participation policy (the
on-device per-round mask of core/selection.py), i.e. the same mechanism
every algorithm — not just FedGiA — shares; benchmarks/participation_bench
extends this sweep to the baselines and the client-sharded path."""
from __future__ import annotations

import jax

from benchmarks.common import M_CLIENTS, make_problem
from repro.config import FedConfig
from repro.core import UniformParticipation, make_algorithm, run_rounds

ALPHAS = [0.1, 0.25, 0.5, 0.75, 1.0]
K0 = 10


def run():
    rows = []
    model, batch, tol = make_problem("linreg", 0)
    # alpha=1.0: the engine mask IS the ADMM/GD split, so the in-algorithm
    # draw is bypassed and fed.alpha is inert
    fed = FedConfig(algorithm="fedgia", num_clients=M_CLIENTS, k0=K0,
                    alpha=1.0, sigma_t=0.15, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    for alpha in ALPHAS:
        res = run_rounds(algo, state, batch, 500, tol=tol,
                         participation=UniformParticipation(M_CLIENTS, alpha))
        rows.append({"alpha": alpha, "cr": 2 * res.rounds_run,
                     "time_s": res.wall_s,
                     "obj": float(res.history["f_xbar"][-1])})
    return rows


def main():
    rows = run()
    print("alpha,CR,time_s,obj")
    for r in rows:
        print(f"{r['alpha']},{r['cr']},{r['time_s']:.3f},{r['obj']:.6f}")
    crs = [r["cr"] for r in rows]
    assert max(crs) <= 3 * min(crs), "alpha should not affect CR strongly at k0=10"
    return rows


if __name__ == "__main__":
    main()
