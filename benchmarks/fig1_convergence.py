"""Paper Fig. 1: FedGiA objective/error vs ITERATIONS (k = rounds * k0) for
k0 in {1,5,10,15,20} — all curves must reach the same objective; bigger k0
needs more iterations (rate O(k0/k), Thm IV.3)."""
from __future__ import annotations

from benchmarks.common import run_algorithm

K0S = [1, 5, 10, 15, 20]


def run():
    rows = []
    for k0 in K0S:
        r = run_algorithm("fedgia_d", "linreg", k0, collect_history=True,
                          max_rounds=400)
        rows.append({
            "k0": k0,
            "iterations": r["rounds"] * k0,
            "rounds": r["rounds"],
            "final_obj": r["obj"],
            "final_err": r["err"],
        })
    return rows


def main():
    rows = run()
    print("k0,iterations,rounds,final_obj,final_err")
    for r in rows:
        print(f"{r['k0']},{r['iterations']},{r['rounds']},"
              f"{r['final_obj']:.6f},{r['final_err']:.3e}")
    objs = [r["final_obj"] for r in rows]
    assert max(objs) - min(objs) < 1e-3, "curves should reach the same objective"
    return rows


if __name__ == "__main__":
    main()
