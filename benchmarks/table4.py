"""Paper Table IV: FedAvg / FedProx / FedPD / FedGiA_D / FedGiA_G across
k0 in {1, 5, 10} — Obj, CR (2 per round), wall time. Plus SCAFFOLD (Table I
comparison set)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_algorithm

ALGOS = ["fedavg", "fedprox", "fedpd", "scaffold", "fedgia_d", "fedgia_g"]
K0S = [1, 5, 10]
TRIALS = 3


def run(problems=("linreg", "logreg", "ncvx_logreg"), trials: int = TRIALS):
    rows = []
    for problem in problems:
        for algo in ALGOS:
            for k0 in K0S:
                rs = [run_algorithm(algo, problem, k0, seed=s) for s in range(trials)]
                rows.append({
                    "problem": problem, "algo": algo, "k0": k0,
                    "obj": float(np.mean([r["obj"] for r in rs])),
                    "cr": float(np.mean([r["cr"] for r in rs])),
                    "time_s": float(np.mean([r["time_s"] for r in rs])),
                    "conv_frac": float(np.mean([r["converged"] for r in rs])),
                })
    return rows


def main():
    rows = run()
    print("problem,algo,k0,obj,CR,time_s,converged_frac")
    for r in rows:
        print(f"{r['problem']},{r['algo']},{r['k0']},{r['obj']:.4f},"
              f"{r['cr']:.1f},{r['time_s']:.3f},{r['conv_frac']:.2f}")
    return rows


if __name__ == "__main__":
    main()
