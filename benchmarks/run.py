"""Benchmark entry point: one module per paper table/figure.

  table4           — paper Table IV (4+ algorithms x k0 x 3 problems)
  fig1_convergence — paper Fig. 1 (k0 effect on iterations-to-converge)
  fig2_k0          — paper Fig. 2 (k0 effect on CR and wall time)
  fig3_alpha       — paper Fig. 3 (selection-fraction effect)
  engine           — scan vs legacy vs sharded vs async round engine
  participation    — in-engine alpha sweep (scan + sharded; one-psum check)
  async            — CR/objective vs max_staleness (stale-x̄ engine)
  wallclock        — time-to-target vs straggler severity (clock engine;
                     also writes BENCH_wallclock.json)
  kernels_bench    — collapsed-vs-unrolled round + FedGiA-vs-FedAvg cost
  roofline         — §Roofline table from the dry-run artifacts

Run everything:  PYTHONPATH=src python -m benchmarks.run
One section:     PYTHONPATH=src python -m benchmarks.run --only engine

Sections whose main() returns data are dumped, machine-readable, to
BENCH_engine.json (path: --json) under their section name — for the
engine section that is round/s for the scan, legacy, sharded and async
paths — so the benchmark trajectory is diffable/plottable instead of
scraped from stdout; CI uploads the file as an artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks import async_bench, engine_bench, fig1_convergence, fig2_k0
from benchmarks import fig3_alpha, kernels_bench, participation_bench
from benchmarks import roofline, table4, wallclock_bench

SECTIONS = {
    "table4": table4.main,
    "fig1": fig1_convergence.main,
    "fig2": fig2_k0.main,
    "fig3": fig3_alpha.main,
    "engine": engine_bench.main,
    "participation": participation_bench.main,
    "async": async_bench.main,
    "wallclock": wallclock_bench.main,
    "kernels": kernels_bench.main,
    "roofline": roofline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SECTIONS), default=None,
                    action="append",
                    help="run only the named section(s); repeatable "
                         "(e.g. --only engine --only kernels)")
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="where to write the machine-readable engine "
                         "results (written when the engine section runs)")
    args = ap.parse_args()
    names = args.only if args.only else list(SECTIONS)
    results = {}
    for name in names:
        print(f"\n===== {name} =====")
        t0 = time.time()
        out = SECTIONS[name]()
        if out is not None:
            results[name] = out
        print(f"----- {name} done in {time.time()-t0:.1f}s -----")
    if results and args.json:
        with open(args.json, "w") as f:
            # sections return plain dict/list rows, but values may be
            # numpy scalars — coerce anything non-JSON to float/str
            json.dump(results, f, indent=2, sort_keys=True,
                      default=lambda o: float(o)
                      if hasattr(o, "__float__") else str(o))
        print(f"\nwrote {args.json} "
              f"({', '.join(sorted(results))})")


if __name__ == "__main__":
    main()
