"""Benchmark entry point: one module per paper table/figure.

  table4           — paper Table IV (4+ algorithms x k0 x 3 problems)
  fig1_convergence — paper Fig. 1 (k0 effect on iterations-to-converge)
  fig2_k0          — paper Fig. 2 (k0 effect on CR and wall time)
  fig3_alpha       — paper Fig. 3 (selection-fraction effect)
  engine           — scan-compiled round engine vs per-round dispatch
  participation    — in-engine alpha sweep (scan + sharded; one-psum check)
  kernels_bench    — collapsed-vs-unrolled round + FedGiA-vs-FedAvg cost
  roofline         — §Roofline table from the dry-run artifacts

Run everything:  PYTHONPATH=src python -m benchmarks.run
One section:     PYTHONPATH=src python -m benchmarks.run --only table4
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import engine_bench, fig1_convergence, fig2_k0, fig3_alpha
from benchmarks import kernels_bench, participation_bench, roofline, table4

SECTIONS = {
    "table4": table4.main,
    "fig1": fig1_convergence.main,
    "fig2": fig2_k0.main,
    "fig3": fig3_alpha.main,
    "engine": engine_bench.main,
    "participation": participation_bench.main,
    "kernels": kernels_bench.main,
    "roofline": roofline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SECTIONS), default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(SECTIONS)
    for name in names:
        print(f"\n===== {name} =====")
        t0 = time.time()
        SECTIONS[name]()
        print(f"----- {name} done in {time.time()-t0:.1f}s -----")


if __name__ == "__main__":
    main()
