"""Shared benchmark harness: runs a federated algorithm to the paper's
stopping rule (eq. 35) and reports Obj / CR / wall time like Table IV.

All runs go through the scan-compiled round engine (core/engine.py) with
the stopping rule evaluated on device; wall times exclude compilation
(the engine pre-compiles its chunks, matching the old warm-up convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core import make_algorithm, run_rounds
from repro.data import linreg_noniid, logreg_data
from repro.models import LeastSquares, LogisticRegression, NonConvexLogistic

# CPU-budget problem sizes (paper: m=128, n in {100, 1024, 200}, d up to 2e5)
M_CLIENTS = 64
N_DIM = 100
D_SAMPLES = 6400
MAX_ROUNDS = 500


def make_problem(name: str, seed: int):
    if name == "linreg":
        model = LeastSquares(N_DIM)
        raw = linreg_noniid(seed, D_SAMPLES, N_DIM, M_CLIENTS)
        tol = 1e-7
    elif name == "logreg":
        model = LogisticRegression(N_DIM)
        raw = logreg_data(seed, D_SAMPLES, N_DIM, M_CLIENTS)
        tol = (5.0 / D_SAMPLES) * 1e-6
    elif name == "ncvx_logreg":
        model = NonConvexLogistic(N_DIM)
        raw = logreg_data(seed, D_SAMPLES, N_DIM, M_CLIENTS)
        tol = (5.0 / D_SAMPLES) * 1e-6
    else:
        raise KeyError(name)
    batch = {k: jnp.asarray(v) for k, v in raw.items()}
    return model, batch, tol


ALGO_HPARAMS = {
    # paper §V.D settings adapted to the synthetic stand-in data
    "fedavg": dict(lr=0.01),
    "fedprox": dict(lr=0.002, prox_mu=1e-4, inner_steps=5),
    "fedpd": dict(lr=0.05, fedpd_eta=1.0, inner_steps=5),
    "scaffold": dict(lr=0.01),
    "fedgia_d": dict(sigma_t=0.15, h_policy="diag_ema", alpha=0.5),
    "fedgia_g": dict(sigma_t=0.15, h_policy="gram", alpha=0.5, collapsed=False),
    "fedgia": dict(sigma_t=0.15, h_policy="scalar", alpha=0.5),
}


def run_algorithm(algo_key: str, problem: str, k0: int, seed: int = 0,
                  max_rounds: int = MAX_ROUNDS, collect_history: bool = False,
                  scan: bool = True):
    model, batch, tol = make_problem(problem, seed)
    hp = dict(ALGO_HPARAMS[algo_key])
    name = "fedgia" if algo_key.startswith("fedgia") else algo_key
    alpha = hp.pop("alpha", 1.0)  # baselines: full participation (paper §V.D)
    fed = FedConfig(algorithm=name, num_clients=M_CLIENTS, k0=k0, alpha=alpha,
                    **hp)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(seed)),
                      jax.random.PRNGKey(seed + 1), init_batch=batch)
    res = run_rounds(algo, state, batch, max_rounds, tol=tol, scan=scan)
    hist = (
        list(zip(res.history["f_xbar"].tolist(),
                 res.history["grad_sq_norm"].tolist()))
        if collect_history else []
    )
    return {
        "algo": algo_key,
        "problem": problem,
        "k0": k0,
        "obj": float(res.history["f_xbar"][-1]),
        "err": float(res.history["grad_sq_norm"][-1]),
        "rounds": res.rounds_run,
        "cr": 2 * res.rounds_run,
        "time_s": res.wall_s,
        "converged": res.stopped_early,
        "history": hist,
    }
