"""Async (stale-x̄) round-engine benchmark: CR / objective vs staleness.

Sweeps `max_staleness` under a heterogeneous periodic arrival process
(client i communicates every p_i rounds — the deterministic straggler
scenario) and reports, per algorithm, the communication rounds to the
paper's stopping rule, the final objective and the staleness actually
used. The interesting read-out is the DEGRADATION CURVE: how much extra
CR a bounded-staleness x̄ costs relative to the synchronous masked run
(max_staleness=0, which is bitwise the synchronous engine).

Second part (subprocess, 8 fake CPU devices): lowers the sharded async
round to HLO and asserts it issues exactly as many MODEL-SIZE all-reduces
as the synchronous masked round — the staleness buffer is per-client
state riding next to z_i, so eq. (11) stays the round's one psum and
overlapping costs no extra communication.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from benchmarks.common import M_CLIENTS, make_problem
from repro.config import FedConfig
from repro.core import make_algorithm, run_rounds
from repro.core.selection import AvailabilityParticipation

STALENESS = [0, 1, 2, 4]
K0 = 10
MAX_ROUNDS = 500
ALGOS = {
    "fedgia_d": dict(algorithm="fedgia", sigma_t=0.15, h_policy="diag_ema",
                     alpha=1.0),  # branch split = the arrival mask
    "scaffold": dict(algorithm="scaffold", lr=0.01),
}

_SHARDED_SCRIPT = textwrap.dedent(
    """
    import re
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import FedConfig
    from repro.core import api, engine, make_algorithm
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)
    fed = FedConfig(algorithm="fedgia", num_clients=m, k0=5, alpha=1.0,
                    sigma_t=0.3, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    s0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                   init_batch=batch)

    def model_size_all_reduces(stale):
        rf = engine.make_round_fn(algo, mesh, masked=True, stale=stale)
        st, b = engine.shard_inputs(algo, s0, batch, mesh)
        args = (st, b, jnp.ones((m,), bool))
        if stale:
            args = args + (api.init_stale_xbar(s0["x"], m, 2),)
        txt = jax.jit(rf).lower(*args).compile().as_text()
        shapes = re.findall(r"= (\\S+) all-reduce\\(", txt)
        return sum(1 for s in shapes if re.search(r"\\[\\d", s))

    sync, asyn = model_size_all_reduces(False), model_size_all_reduces(True)
    assert asyn == sync, (
        f"async round changed the model-size all-reduce count: "
        f"{sync} -> {asyn}")
    print(f"ASYNC_SHARDED_OK model_size_all_reduces={asyn}")
    """
)


def _arrival(m: int, horizon: int) -> AvailabilityParticipation:
    # heterogeneous speeds 1..4 rounds, deterministic (variance-free sweep)
    return AvailabilityParticipation.from_periods(
        m, 1 + (np.arange(m) % 4), horizon=horizon
    )


def run():
    rows = []
    model, batch, tol = make_problem("linreg", 0)
    for algo_key, hp in ALGOS.items():
        fed = FedConfig(num_clients=M_CLIENTS, k0=K0, **hp)
        algo = make_algorithm(fed, model.loss, model=model)
        state = algo.init(model.init(jax.random.PRNGKey(0)),
                          jax.random.PRNGKey(1), init_batch=batch)
        pol = _arrival(M_CLIENTS, MAX_ROUNDS)
        for s in STALENESS:
            res = run_rounds(algo, state, batch, MAX_ROUNDS, tol=tol,
                             participation=pol, async_rounds=True,
                             max_staleness=s)
            rows.append({
                "algo": algo_key,
                "max_staleness": s,
                "staleness_seen": int(res.history["staleness_max"].max()),
                "cr": 2 * res.rounds_run,
                "time_s": res.wall_s,
                "obj": float(res.history["f_xbar"][-1]),
                "converged": res.stopped_early,
            })
    return rows


def run_sharded() -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ASYNC_SHARDED_OK" in out.stdout, out.stdout + out.stderr
    return out.stdout


def main():
    rows = run()
    print("algo,max_staleness,staleness_seen,CR,time_s,obj,converged")
    for r in rows:
        print(f"{r['algo']},{r['max_staleness']},{r['staleness_seen']},"
              f"{r['cr']},{r['time_s']:.3f},{r['obj']:.6f},{r['converged']}")
    # bounded staleness must stay bounded, and the s=0 column is the
    # synchronous reference the degradation is measured against
    for r in rows:
        assert r["staleness_seen"] <= r["max_staleness"], r
    crs = [r["cr"] for r in rows if r["algo"] == "fedgia_d" and r["converged"]]
    if len(crs) >= 2:
        assert max(crs) <= 5 * min(crs), (
            f"staleness blew up FedGiA CR beyond the expected band: {crs}")
    print("\n-- sharded async path (8 fake devices) --")
    print(run_sharded())
    return rows


if __name__ == "__main__":
    main()
