"""Kernel/algorithm micro-benchmarks (CPU wall time; the analytic TPU
roofline numbers live in benchmarks/roofline.py).

  1. collapsed vs unrolled FedGiA round (DESIGN §6 B1): the measurable
     computational-efficiency win of the closed-form round.
  2. FedGiA vs FedAvg per-round cost (paper Table I: one gradient vs k0).
  3. flat (m, N) round update vs the per-leaf pytree twin at model scale —
     the elementwise pass `kernels/fedgia_update` fuses on TPU, isolated
     from the gradient compute (the jnp twins on CPU; the Pallas kernel
     itself is only meaningfully timed on TPU hardware).

`main()` returns the rows machine-readably; benchmarks/run.py folds them
into BENCH_engine.json under the "kernels" section so the flat/kernel
round-update cost is tracked round-over-round next to the engine paths.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig
from repro.core import make_algorithm
from repro.data import linreg_noniid
from repro.kernels.fedgia_update import fedgia_update_flat
from repro.models import LeastSquares


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def bench_collapsed_vs_unrolled(n=200_000, m=16, k0=20):
    model = LeastSquares(100)
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, 3200, 100, m).items()}
    rows = []
    for collapsed in (True, False):
        fed = FedConfig(algorithm="fedgia", num_clients=m, k0=k0,
                        collapsed=collapsed, sigma_t=0.2, h_policy="diag_ema")
        algo = make_algorithm(fed, model.loss, model=model)
        state = algo.init(model.init(jax.random.PRNGKey(0)),
                          jax.random.PRNGKey(1), init_batch=batch)
        rnd = jax.jit(lambda s, b: algo.round(s, b)[0]["z"])
        us = _time(rnd, state, batch)
        rows.append((f"fedgia_round_{'collapsed' if collapsed else 'unrolled'}_k0{k0}",
                     us))
    return rows


def bench_fedgia_vs_fedavg(m=16, k0=10):
    model = LeastSquares(100)
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, 6400, 100, m).items()}
    rows = []
    for name in ("fedgia", "fedavg"):
        fed = FedConfig(algorithm=name, num_clients=m, k0=k0, sigma_t=0.2,
                        lr=0.01, h_policy="scalar")
        algo = make_algorithm(fed, model.loss, model=model)
        state = algo.init(model.init(jax.random.PRNGKey(0)),
                          jax.random.PRNGKey(1), init_batch=batch)
        rnd = jax.jit(lambda s, b: algo.round(s, b)[0]["x"])
        rows.append((f"{name}_round_k0{k0}", _time(rnd, state, batch)))
    return rows


def bench_flat_update(n=200_000, m=16, k0=20, leaves=10):
    """The round's ADMM/GD elementwise update at model scale: one fused
    (m, n) pass (the flat engine's hot path, = the Pallas kernel's math)
    vs the same arithmetic split over a `leaves`-leaf pytree (what the
    per-leaf round dispatches), vs the k0-step unrolled oracle."""
    rng = np.random.default_rng(0)
    arr = lambda: jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    xbar_c, g, pi = arr(), arr(), arr()
    h = jnp.asarray(rng.uniform(0.1, 2.0, (m, n)), jnp.float32)
    sel = jnp.asarray(rng.random(m) < 0.5)
    sigma = jnp.float32(0.4)

    def collapsed(xb, gg, p0, hh):
        d = 1.0 / (hh / m + sigma)
        a = 1.0 - sigma * d
        b = p0 + gg
        ak1 = a ** (k0 - 1)
        pi_a = ak1 * a * b - gg
        x_a = xb + (-d * ak1 * b)
        pick = sel.reshape((m,) + (1,) * (xb.ndim - 1))
        pi_n = jnp.where(pick, pi_a, -gg)
        x_n = jnp.where(pick, x_a, xb)
        return x_n, pi_n, x_n + pi_n / sigma

    flat_fn = jax.jit(collapsed)

    split = np.array_split(np.arange(n), leaves)
    cut = lambda v: [v[:, idx] for idx in split]
    xs, gs, ps, hs = cut(xbar_c), cut(g), cut(pi), cut(h)

    @jax.jit
    def leafwise(xs, gs, ps, hs):
        return [collapsed(a, b, c, d) for a, b, c, d in zip(xs, gs, ps, hs)]

    unrolled = jax.jit(
        lambda: fedgia_update_flat(xbar_c, g, pi, h, sel, sigma, m, k0=k0,
                                   use_kernel=False))
    return [
        (f"fedgia_update_flat_fused_m{m}_n{n}", _time(flat_fn, xbar_c, g, pi, h)),
        (f"fedgia_update_pytree_{leaves}leaf_m{m}_n{n}",
         _time(leafwise, xs, gs, ps, hs)),
        (f"fedgia_update_unrolled_ref_k0{k0}", _time(unrolled)),
    ]


def main():
    rows = []
    rows += bench_collapsed_vs_unrolled()
    rows += bench_fedgia_vs_fedavg()
    rows += bench_flat_update()
    for name, us in rows:
        print(f"{name},{us:.1f},")
    # machine-readable: benchmarks/run.py dumps this under "kernels" in
    # BENCH_engine.json so the flat/kernel update cost is tracked next to
    # the engine round/s trajectory
    return {"unit": "us", "micro": {name: us for name, us in rows}}


if __name__ == "__main__":
    main()
