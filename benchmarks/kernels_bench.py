"""Kernel/algorithm micro-benchmarks (CPU wall time; the analytic TPU
roofline numbers live in benchmarks/roofline.py).

  1. collapsed vs unrolled FedGiA round (DESIGN §6 B1): the measurable
     computational-efficiency win of the closed-form round.
  2. FedGiA vs FedAvg per-round cost (paper Table I: one gradient vs k0).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig
from repro.core import make_algorithm
from repro.data import linreg_noniid
from repro.models import LeastSquares


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def bench_collapsed_vs_unrolled(n=200_000, m=16, k0=20):
    model = LeastSquares(100)
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, 3200, 100, m).items()}
    rows = []
    for collapsed in (True, False):
        fed = FedConfig(algorithm="fedgia", num_clients=m, k0=k0,
                        collapsed=collapsed, sigma_t=0.2, h_policy="diag_ema")
        algo = make_algorithm(fed, model.loss, model=model)
        state = algo.init(model.init(jax.random.PRNGKey(0)),
                          jax.random.PRNGKey(1), init_batch=batch)
        rnd = jax.jit(lambda s, b: algo.round(s, b)[0]["z"])
        us = _time(rnd, state, batch)
        rows.append((f"fedgia_round_{'collapsed' if collapsed else 'unrolled'}_k0{k0}",
                     us))
    return rows


def bench_fedgia_vs_fedavg(m=16, k0=10):
    model = LeastSquares(100)
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, 6400, 100, m).items()}
    rows = []
    for name in ("fedgia", "fedavg"):
        fed = FedConfig(algorithm=name, num_clients=m, k0=k0, sigma_t=0.2,
                        lr=0.01, h_policy="scalar")
        algo = make_algorithm(fed, model.loss, model=model)
        state = algo.init(model.init(jax.random.PRNGKey(0)),
                          jax.random.PRNGKey(1), init_batch=batch)
        rnd = jax.jit(lambda s, b: algo.round(s, b)[0]["x"])
        rows.append((f"{name}_round_k0{k0}", _time(rnd, state, batch)))
    return rows


def main():
    rows = []
    rows += bench_collapsed_vs_unrolled()
    rows += bench_fedgia_vs_fedavg()
    for name, us in rows:
        print(f"{name},{us:.1f},")
    return rows


if __name__ == "__main__":
    main()
