from repro.data.synthetic import (
    linreg_noniid,
    logreg_data,
    make_client_batches,
)
from repro.data.partition import dirichlet_partition, equal_partition
from repro.data.tokens import synthetic_lm_batches, synthetic_batch_for
