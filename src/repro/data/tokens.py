"""Synthetic LM data: deterministic pseudo-token streams for the
transformer architectures (markov-ish structure so loss can improve) and
ShapeDtypeStruct-compatible batch builders for every input_mode."""
from __future__ import annotations

import numpy as np

from repro.config import ModelConfig


def synthetic_lm_batches(
    seed: int, vocab: int, m: int, batch_per_client: int, seq_len: int
):
    """(m, B, S+1) int32 token stream with a planted bigram structure."""
    rng = np.random.default_rng(seed)
    # per-client bigram transition bias -> non-iid clients
    out = np.empty((m, batch_per_client, seq_len + 1), np.int32)
    for i in range(m):
        shift = rng.integers(1, max(vocab // 2, 2))
        toks = rng.integers(0, vocab, size=(batch_per_client, seq_len + 1))
        # half the positions follow t_{j+1} = (t_j + shift) % vocab
        follow = rng.uniform(size=(batch_per_client, seq_len)) < 0.5
        for j in range(seq_len):
            nxt = (toks[:, j] + shift) % vocab
            toks[:, j + 1] = np.where(follow[:, j], nxt, toks[:, j + 1])
        out[i] = toks
    return out


def synthetic_batch_for(
    cfg: ModelConfig, m: int, batch_per_client: int, seq_len: int, seed: int = 0
):
    """A stacked federated batch (leading client axis) for any input_mode."""
    rng = np.random.default_rng(seed)
    tokens = synthetic_lm_batches(seed, cfg.vocab_size, m, batch_per_client, seq_len)
    if cfg.input_mode == "tokens":
        return {"tokens": tokens}
    if cfg.input_mode == "embeds":
        emb = rng.standard_normal(
            (m, batch_per_client, seq_len, cfg.d_model)
        ).astype(np.float32)
        return {"embeds": emb, "labels": tokens[..., :seq_len]}
    # tokens+embeds (vlm): patch-embedding prefix + text tokens
    P = cfg.embed_prefix_len
    emb = rng.standard_normal((m, batch_per_client, P, cfg.d_model)).astype(
        np.float32
    )
    return {"embeds": emb, "tokens": tokens}
