"""Synthetic datasets reproducing the paper's experiments.

Example V.1 (linear regression, non-i.i.d.): d samples drawn from a MIXTURE
of three distributions — standard normal, Student's t (df=5), uniform on
[-5, 5] — shuffled and split into m parts with heterogeneous sizes
d_i ~ uniform{0.5 d/m .. 1.5 d/m} (here: random split, padded + masked so
the stacked client axis is rectangular).

Examples V.2/V.3 (logistic regression): the paper uses the qot/sct real
datasets; offline we generate a synthetic classification set of matching
dimensions (n features, d samples) with a planted separator — documented
substitution, see EXPERIMENTS.md.
"""
from __future__ import annotations

import numpy as np


def _mixture_features(rng: np.random.Generator, d: int, n: int) -> np.ndarray:
    thirds = [d // 3, d // 3, d - 2 * (d // 3)]
    parts = [
        rng.standard_normal((thirds[0], n)),
        rng.standard_t(df=5, size=(thirds[1], n)),
        rng.uniform(-5.0, 5.0, size=(thirds[2], n)),
    ]
    A = np.concatenate(parts, axis=0)
    rng.shuffle(A, axis=0)
    return A.astype(np.float32)


def linreg_noniid(seed: int, d: int, n: int, m: int):
    """Paper Example V.1. Returns stacked client batches
    {"A": (m, dmax, n), "b": (m, dmax), "mask": (m, dmax)}."""
    rng = np.random.default_rng(seed)
    A = _mixture_features(rng, d, n)
    x_star = rng.standard_normal(n).astype(np.float32)
    b = A @ x_star + 0.1 * rng.standard_normal(d).astype(np.float32)
    sizes = _heterogeneous_sizes(rng, d, m)
    return make_client_batches({"A": A, "b": b}, sizes)


def logreg_data(seed: int, d: int, n: int, m: int):
    """Synthetic stand-in for qot/sct: planted-separator classification."""
    rng = np.random.default_rng(seed)
    A = _mixture_features(rng, d, n)
    w = rng.standard_normal(n).astype(np.float32) / np.sqrt(n)
    p = 1.0 / (1.0 + np.exp(-(A @ w + 0.3 * rng.standard_normal(d))))
    b = (rng.uniform(size=d) < p).astype(np.float32)
    sizes = _heterogeneous_sizes(rng, d, m)
    return make_client_batches({"A": A, "b": b}, sizes)


def _heterogeneous_sizes(rng, d: int, m: int):
    """d_i ~ uniform{floor(0.5 d/m) .. ceil(1.5 d/m)}, summing to d."""
    base = d / m
    lo, hi = max(1, int(0.5 * base)), max(2, int(1.5 * base))
    sizes = rng.integers(lo, hi + 1, size=m)
    # rescale to sum d while keeping every d_i within [lo, hi]
    while sizes.sum() > d:
        cand = np.flatnonzero(sizes > lo)
        sizes[rng.choice(cand if len(cand) else np.arange(m))] -= 1
    while sizes.sum() < d:
        cand = np.flatnonzero(sizes < hi)
        sizes[rng.choice(cand if len(cand) else np.arange(m))] += 1
    sizes = np.maximum(sizes, 1)
    return sizes.tolist()


def make_client_batches(data: dict, sizes):
    """Split row-wise into len(sizes) clients, pad to max size, add mask."""
    m = len(sizes)
    dmax = max(sizes)
    out = {k: [] for k in data}
    masks = []
    start = 0
    for s in sizes:
        for k, v in data.items():
            chunk = v[start : start + s]
            pad = [(0, dmax - s)] + [(0, 0)] * (chunk.ndim - 1)
            out[k].append(np.pad(chunk, pad))
        mask = np.zeros(dmax, np.float32)
        mask[:s] = 1.0
        masks.append(mask)
        start += s
    batch = {k: np.stack(v) for k, v in out.items()}
    batch["mask"] = np.stack(masks)
    return batch
