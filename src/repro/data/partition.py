"""Federated data partitioners (how client heterogeneity is created)."""
from __future__ import annotations

import numpy as np


def equal_partition(num_items: int, m: int):
    base = num_items // m
    sizes = [base] * m
    for i in range(num_items - base * m):
        sizes[i] += 1
    return sizes


def dirichlet_partition(labels: np.ndarray, m: int, alpha: float, seed: int = 0):
    """Label-skew non-iid partition (Dirichlet prior over client shares).
    Returns a list of index arrays, one per client."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    client_idx = [[] for _ in range(m)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        shares = rng.dirichlet(alpha * np.ones(m))
        cuts = (np.cumsum(shares)[:-1] * len(idx)).astype(int)
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    return [np.array(sorted(ix)) for ix in client_idx]
