"""Production mesh factory. A FUNCTION (not a module constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: `axis_types` appeared after
    0.4.37 (and AxisType.Auto is its default); pass it only when it exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-pod meshes: single pod 16x16 = 256 chips (data, model);
    multi-pod 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1, pod: int = 0):
    """Small mesh over however many (possibly fake) devices exist — used by
    the multi-device integration tests."""
    if pod:
        return _make_mesh((pod, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))
