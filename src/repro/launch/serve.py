"""Serving driver: prefill a batch of requests, then decode autoregressively.

CPU-runnable with --reduced; the same jitted step functions are what the
dry-run lowers for the production mesh. The decode loop is scan-compiled
through the round engine's `scan_steps` (core/engine.py) — the whole
generation is ONE dispatch instead of one per token; `--no-scan` keeps the
legacy per-token loop.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_architectures
from repro.core.engine import scan_steps
from repro.models import Transformer
from repro.utils import get_logger

log = get_logger("serve")


def serve(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Transformer(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)

    B = args.batch
    cache_len = args.prompt_len + args.gen
    window = cfg.sliding_window if args.long_context else None

    prompts = jax.random.randint(
        rng, (B, args.prompt_len), 0, cfg.vocab_size, dtype=jnp.int32
    )

    prefill = jax.jit(
        lambda p, t: model.prefill(p, tokens=t, cache_len=cache_len, window=window)
    )

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    if getattr(args, "no_scan", False):
        decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, window=window)
        )
        out = [tokens]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            logits, cache = decode(params, cache, tokens, pos)
            tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(tokens)
        jax.block_until_ready(out[-1])
        t_decode = time.time() - t0
        gen = np.asarray(jnp.concatenate(out, axis=1))
    else:
        # scan-compiled decode: the whole generation is one dispatch
        def step(carry, p):
            c, t, pos = carry
            logits, c = model.decode_step(p, c, t, pos, window=window)
            t = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            return (c, t, pos + 1), t

        run = scan_steps(step, args.gen - 1)
        carry0 = (cache, tokens, jnp.asarray(args.prompt_len, jnp.int32))
        t0 = time.time()
        (cache, _, _), rest = run(carry0, params)
        jax.block_until_ready(rest)
        t_decode = time.time() - t0
        # rest: (gen-1, B, 1) -> (B, gen-1); prepend the prefill's argmax
        gen = np.asarray(
            jnp.concatenate([tokens, jnp.swapaxes(rest[..., 0], 0, 1)], axis=1)
        )
    log.info("prefill %.3fs (%d tokens)  decode %.3fs (%.1f tok/s/req)",
             t_prefill, B * args.prompt_len, t_decode,
             (args.gen - 1) / max(t_decode, 1e-9))
    log.info("generated[0,:16] = %s", gen[0, :16].tolist())
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_architectures(), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--long-context", action="store_true")
    ap.add_argument("--no-scan", action="store_true",
                    help="legacy per-token decode dispatch (debugging)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
