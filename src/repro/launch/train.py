"""Federated training driver.

Runs REAL training (paper examples or transformer archs at reduced scale on
CPU; the same code path drives the production mesh on TPU) with any of the
five federated algorithms.

Rounds are driven by the scan-compiled round engine (core/engine.py):
chunks of rounds compile into one lax.scan with the tolerance check on
device, so the host is not in the per-round loop. `--no-scan` restores the
legacy per-round dispatch for debugging; `--shard-clients N` splits the
client axis over an N-way `data` mesh axis (requires >= N devices).

Examples:
  PYTHONPATH=src python -m repro.launch.train --problem linreg --algo fedgia \
      --clients 128 --k0 10 --rounds 200 --tol 1e-7
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --reduced \
      --algo fedgia --clients 4 --rounds 20 --seq-len 64 --batch 2
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.config import FedConfig
from repro.configs import get_config, list_architectures
from repro.core import make_algorithm, run_rounds
from repro.data import linreg_noniid, logreg_data
from repro.data.tokens import synthetic_batch_for
from repro.models import (
    LeastSquares,
    LogisticRegression,
    NonConvexLogistic,
    Transformer,
)
from repro.utils import get_logger

log = get_logger("train")


def build_problem(args):
    if args.arch:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        model = Transformer(cfg)
        batch = synthetic_batch_for(
            cfg, args.clients, args.batch, args.seq_len, seed=args.seed
        )
        batch = jax.tree.map(jnp.asarray, batch)
        params0 = model.init(jax.random.PRNGKey(args.seed))
        return model, model.loss, params0, batch
    n = args.dim
    if args.problem == "linreg":
        model = LeastSquares(n)
        raw = linreg_noniid(args.seed, args.samples, n, args.clients)
    elif args.problem == "logreg":
        model = LogisticRegression(n)
        raw = logreg_data(args.seed, args.samples, n, args.clients)
    else:
        model = NonConvexLogistic(n)
        raw = logreg_data(args.seed, args.samples, n, args.clients)
    batch = jax.tree.map(jnp.asarray, raw)
    params0 = model.init(jax.random.PRNGKey(args.seed))
    return model, model.loss, params0, batch


def train(args) -> dict:
    model, loss_fn, params0, batch = build_problem(args)
    fed = FedConfig(
        algorithm=args.algo,
        num_clients=args.clients,
        k0=args.k0,
        alpha=args.alpha,
        sigma_t=args.sigma_t,
        h_policy=args.h_policy,
        collapsed=not args.unrolled,
        lr=args.lr,
        auto_lipschitz=args.arch is not None,
    )
    algo = make_algorithm(fed, loss_fn, model=model)
    state = algo.init(params0, jax.random.PRNGKey(args.seed + 1), init_batch=batch)

    # engine knobs default off so programmatic callers can pass a bare
    # Namespace with only the legacy fields
    shard_clients = getattr(args, "shard_clients", 0)
    mesh = None
    if shard_clients > 1:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(data=shard_clients)

    res = run_rounds(
        algo, state, batch, args.rounds,
        tol=args.tol, scan=not getattr(args, "no_scan", False),
        chunk_size=getattr(args, "chunk", 0), mesh=mesh,
    )
    history = [
        {"round": r, "f": float(res.history["f_xbar"][r]),
         "err": float(res.history["grad_sq_norm"][r])}
        for r in range(res.rounds_run)
    ]
    for h in history:
        if h["round"] % args.log_every == 0 or h["round"] == res.rounds_run - 1:
            log.info("round %4d  f=%.6f  |grad|^2=%.3e",
                     h["round"], h["f"], h["err"])
    if res.stopped_early:
        log.info("tolerance reached at round %d", res.rounds_run - 1)
    result = {
        "algo": args.algo,
        "rounds": res.rounds_run,
        "cr": 2 * res.rounds_run,
        "final_f": history[-1]["f"],
        "final_err": history[-1]["err"],
        "wall_s": res.wall_s,
        "history": history,
    }
    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, res.rounds_run, res.state,
                        extra={"algo": args.algo})
        log.info("checkpoint written to %s", args.checkpoint_dir)
    log.info(
        "done: %d rounds (CR=%d) in %.2fs  f=%.6f err=%.2e",
        result["rounds"], result["cr"], res.wall_s, result["final_f"],
        result["final_err"],
    )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="linreg",
                    choices=["linreg", "logreg", "ncvx_logreg"])
    ap.add_argument("--arch", choices=list_architectures())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--algo", default="fedgia",
                    choices=["fedgia", "fedavg", "fedprox", "fedpd", "scaffold"])
    ap.add_argument("--clients", type=int, default=128)
    ap.add_argument("--k0", type=int, default=5)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--sigma-t", type=float, default=0.15)
    ap.add_argument("--h-policy", default="scalar",
                    choices=["scalar", "diag_ema", "gram"])
    ap.add_argument("--unrolled", action="store_true")
    ap.add_argument("--no-scan", action="store_true",
                    help="legacy per-round dispatch loop (debugging)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="rounds per compiled scan chunk (0 = auto)")
    ap.add_argument("--shard-clients", type=int, default=0,
                    help="shard the client axis over an N-way data mesh")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--tol", type=float, default=1e-7)
    ap.add_argument("--dim", type=int, default=100)
    ap.add_argument("--samples", type=int, default=12800)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()
    train(args)


if __name__ == "__main__":
    main()
