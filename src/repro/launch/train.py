"""Federated training driver.

Runs REAL training (paper examples or transformer archs at reduced scale on
CPU; the same code path drives the production mesh on TPU) with any of the
five federated algorithms.

Rounds are driven by the scan-compiled round engine (core/engine.py):
chunks of rounds compile into one lax.scan with the tolerance check on
device, so the host is not in the per-round loop. `--no-scan` restores the
legacy per-round dispatch for debugging; `--shard-clients N` splits the
client axis over an N-way `data` mesh axis (requires >= N devices);
`--chunk auto` times the candidate chunk lengths on the live run and
keeps the fastest.

Rounds run on the FLAT client-state buffer by default: the model pytree
is raveled once into contiguous (m, N) arrays, eq. (11) is one
model-size reduction and FedGiA's branch update one fused pass (the
batched Pallas kernel on TPU — `--kernel`). `--no-flat` restores the
per-leaf pytree rounds (bitwise-equal single-device, tests/test_flat.py).

`--participation` moves client selection into the engine: a fresh
per-round mask is drawn on device (inside the compiled scan) and fed to
every algorithm — FedGiA uses it as its ADMM/GD branch split, the
baselines freeze masked-out clients (see docs/engine.md).

`--async` turns the participation mask into an ARRIVAL process and runs
staleness-aware overlapped rounds: a straggler works against the x̄ it
last downloaded, at most `--max-staleness` rounds old (see docs/async.md).
`--max-staleness 0` is bitwise identical to the synchronous masked run.

`--store active` swaps the dense (m, N) round working set for a packed
participant tile: each round gathers the selected clients' rows, runs
the local work at O(|C|) instead of O(m), and scatters per-client state
back into the resident buffers. States are bitwise-equal to the dense
store; loss/gradient diagnostics become participant means. This is what
makes m=10^6 clients at alpha=10^-4 tractable (engine_bench `active_1m`).

`--clock` replaces the sampled arrival process with a WALL-CLOCK
simulation (core/clock.py): per-client compute times (`--client-speeds`)
drive event-driven rounds whose arrival mask is derived from simulated
finish times, and the run reports simulated seconds alongside CR.
`--stale-weighting poly|exp` downweights stale contributions in the
aggregation (eq. 11) by decay in anchor age (`--stale-decay`).

`--overlap scatter` splits eq. (11)'s one all-reduce into an early
reduce-scatter of this round's contribution plus a deferred all-gather of
the consensus shard at the TOP of the next round, so the model-size wire
transfer hides behind the next round's local compute (the clock credits
min(compute, comm) per round). `--pod P` spans the sharded client axis
over a compound ("pod", "data") mesh — P pods of `--shard-clients`/P
devices each — bitwise identical to the flat data axis
(docs/engine.md#overlapped-collectives).

`--compression bf16|int8|topk` quantizes/sparsifies the uplink on the
flat comm buffer (core/compress.py, decompress-before-reduce — the round
keeps its ONE model-size all-reduce); `--error-feedback` carries the
per-client codec residual so the error telescopes; `--bandwidth-bps`
makes the clock's comm time BYTE-ACCURATE (the codec's exact wire size
prices each round), so compression shows up as simulated time-to-target,
not just fewer bits (docs/compression.md).

`--faults crash,nan,...` injects client faults into the decoded uplink ON
DEVICE (stateless per-round keys — deterministic everywhere, including
across `--resume`); `--screening` (+ `--clip-norm`) drops non-finite and
clips oversized uploads as a rider on eq. (11)'s ONE collective;
`--quorum` degrades under-quorum rounds to recorded no-ops and
`--deadline-s` closes each simulated round at a wall-clock deadline;
`--watchdog` rolls the state back to the best snapshot after sustained
divergence; `--checkpoint-every N --checkpoint-dir D` snapshots the full
round carry and `--resume` restores it BITWISE (docs/faults.md).

Examples:
  PYTHONPATH=src python -m repro.launch.train --problem linreg --algo fedgia \
      --clients 128 --k0 10 --rounds 200 --tol 1e-7
  PYTHONPATH=src python -m repro.launch.train --problem linreg --algo scaffold \
      --clients 64 --rounds 100 --participation uniform --alpha 0.25
  PYTHONPATH=src python -m repro.launch.train --problem linreg --algo fedgia \
      --clients 64 --rounds 200 --clock constant --client-speeds "$(python -c \
      'print(",".join(str(1+i%4) for i in range(64)))")" \
      --max-staleness 4 --stale-weighting poly
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --reduced \
      --algo fedgia --clients 4 --rounds 20 --seq-len 64 --batch 2
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.config import FedConfig
from repro.configs import get_config, list_architectures
from repro.core import (
    Screening,
    make_algorithm,
    make_clock,
    make_faults,
    make_policy,
    run_rounds,
)
from repro.core.clock import CLOCKS
from repro.core.selection import POLICIES
from repro.data import linreg_noniid, logreg_data
from repro.data.tokens import synthetic_batch_for
from repro.models import (
    LeastSquares,
    LogisticRegression,
    NonConvexLogistic,
    Transformer,
)
from repro.utils import get_logger

log = get_logger("train")


def build_problem(args):
    if args.arch:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        model = Transformer(cfg)
        batch = synthetic_batch_for(
            cfg, args.clients, args.batch, args.seq_len, seed=args.seed
        )
        batch = jax.tree.map(jnp.asarray, batch)
        params0 = model.init(jax.random.PRNGKey(args.seed))
        return model, model.loss, params0, batch
    n = args.dim
    if args.problem == "linreg":
        model = LeastSquares(n)
        raw = linreg_noniid(args.seed, args.samples, n, args.clients)
    elif args.problem == "logreg":
        model = LogisticRegression(n)
        raw = logreg_data(args.seed, args.samples, n, args.clients)
    else:
        model = NonConvexLogistic(n)
        raw = logreg_data(args.seed, args.samples, n, args.clients)
    batch = jax.tree.map(jnp.asarray, raw)
    params0 = model.init(jax.random.PRNGKey(args.seed))
    return model, model.loss, params0, batch


def _parse_csv(value: str, n: int, flag: str, cast):
    try:
        items = [cast(v) for v in value.split(",")]
    except ValueError as e:
        raise SystemExit(f"{flag}: {e}")
    if len(items) != n:
        raise SystemExit(f"{flag} needs {n} values, got {len(items)}")
    return items


def validate_flags(args) -> dict:
    """Cross-flag validation for the engine knobs, shared by `train` and
    testable without building a problem (tests/test_train_flags.py).

    Rejects (SystemExit): `--max-staleness` / `--stale-weighting` without
    `--async` (or `--clock`, which implies it); `--arrival-periods`
    without the periodic policy; `--client-weights` without the weighted
    policy; `--client-speeds` without `--clock`; `--clock` combined with
    an explicit `--participation` (the clock DERIVES the arrival mask);
    `--clock trace` (library-level — needs a duration table); a
    non-positive `--stale-decay` with a decaying weighting; a `--chunk`
    that is neither an int nor "auto"; `--chunk auto` with `--no-scan`
    (the legacy loop has no chunks); `--store active` with `--no-flat`
    (the active store packs the FLAT buffers) or without a participant
    source (`--participation` or `--clock` — there is nothing to pack
    a tile from under legacy full participation); a lossy `--compression`
    with `--no-flat` (codecs run on the flat comm buffer);
    `--error-feedback` without a lossy codec (the identity residual is
    always zero); `--topk-frac` without `--compression topk` or outside
    (0, 1]; `--bandwidth-bps` without `--clock` (byte-accurate comm time
    is a clock feature) or non-positive; `--overlap scatter` with
    `--no-flat` (the carry slot lives on the flat buffers); `--pod`
    without `--shard-clients`, or a `--shard-clients` not divisible by
    `--pod` (each pod holds shard_clients/pod devices); `--store
    offload` with `--shard-clients` (the host/device split is
    single-device), `--overlap scatter` (no carry slot in the
    host-driven loop) or `--chunk auto` (no chunks to tune);
    `--aggregate packed` with `--store dense` (the packed sum needs the
    participant tile); an unknown `--faults` kind, or `--faults` /
    `--screening` with `--no-flat` (both operate on the flat comm
    buffer); `--fault-rate` without `--faults`, with a rate outside
    [0, 1] or a list length that is neither 1 nor len(kinds);
    `--clip-norm` without `--screening`; `--quorum` outside [1, m] or
    without a source of non-arrival (`--participation`, `--clock`,
    `--faults` or `--screening`); `--deadline-s` without `--clock` (the
    deadline cuts SIMULATED rounds) or without `--quorum` (a deadline
    round can close with zero arrivals); `--watchdog-patience` /
    `--watchdog-factor` without `--watchdog`, a patience < 1, a factor
    <= 1, or `--watchdog` with `--store offload` (the snapshot would
    double host residency); `--checkpoint-every` / `--resume` without
    `--checkpoint-dir`, with `--shard-clients`, with `--chunk auto`, or
    with `--no-scan` on a non-offload store (checkpointing rides the
    chunked scan driver / the offload loop).

    Returns the resolved engine knobs: participation kind, clock kind,
    whether async rounds are on (a clock implies them), the parsed
    per-client lists (weights / periods / speeds, or None), the chunk
    size (int or "auto"), whether the flat round path is on, the
    FedConfig kernel knobs resolved from `--kernel`, and the compression
    knobs (codec name or None, error_feedback, topk_frac, bandwidth_bps
    or None).
    """
    kind = getattr(args, "participation", "full")
    clock_kind = getattr(args, "clock", "none")
    async_rounds = getattr(args, "async_rounds", False) or clock_kind != "none"
    chunk = getattr(args, "chunk", "0")
    if chunk != "auto":
        try:
            chunk = int(chunk)
        except ValueError:
            raise SystemExit(
                f"--chunk must be an integer or 'auto', got {chunk!r}")
    elif getattr(args, "no_scan", False):
        raise SystemExit(
            "--chunk auto tunes the scan chunk length and cannot be "
            "combined with --no-scan")
    elif getattr(args, "shard_clients", 0) > 1:
        raise SystemExit(
            "--chunk auto times AOT-precompiled chunks, which the "
            "sharded path does not have — pass a fixed --chunk with "
            "--shard-clients")
    kernel_arg = getattr(args, "kernel", "auto")
    use_kernel = {"auto": None, "on": True, "off": False,
                  "interpret": True}[kernel_arg]
    kernel_interpret = kernel_arg == "interpret"
    if kernel_arg in ("on", "interpret") and getattr(args, "no_flat", False):
        raise SystemExit(
            "--kernel on/interpret requires the flat round path "
            "(drop --no-flat)")
    store = getattr(args, "store", "dense")
    if store in ("active", "offload"):
        if getattr(args, "no_flat", False):
            raise SystemExit(
                f"--store {store} packs the flat (m, N) client buffers and "
                "requires the flat round path (drop --no-flat)")
        if kind == "full" and clock_kind == "none":
            raise SystemExit(
                f"--store {store} needs a per-round participant set to pack "
                "the tile from: pass --participation (uniform/weighted/"
                "cyclic give the fixed-size tile; others bound it by m) "
                "or --clock")
    if store == "offload":
        if getattr(args, "shard_clients", 0) > 1:
            raise SystemExit(
                "--store offload is the single-device host/device split — "
                "under --shard-clients the resident buffers are already "
                "spread over devices; use --store active")
        if getattr(args, "overlap", "off") == "scatter":
            raise SystemExit(
                "--store offload runs the host-driven tile loop — the "
                "overlapped-collective carry slot (--overlap scatter) "
                "does not ride it")
        if chunk == "auto":
            raise SystemExit(
                "--chunk auto tunes the scan chunk length — the "
                "host-driven offload loop (--store offload) has no chunks")
    aggregate = getattr(args, "aggregate", "dense")
    if aggregate == "packed" and store == "dense":
        raise SystemExit(
            "--aggregate packed sums the packed participant tile — it "
            "requires --store active or --store offload")
    if clock_kind != "none" and kind != "full":
        raise SystemExit(
            "--clock derives the arrival mask from simulated finish times "
            "and cannot be combined with --participation"
        )
    if clock_kind == "trace":
        raise SystemExit(
            "--clock trace is library-level (it needs a (T, m) duration "
            "table): build core.clock.TraceClock and pass it to "
            "run_rounds(clock=...) programmatically"
        )
    if (getattr(args, "stale_weighting", "uniform") != "uniform"
            and getattr(args, "stale_decay", 1.0) <= 0):
        raise SystemExit("--stale-decay must be > 0")
    if getattr(args, "max_staleness", 0) and not async_rounds:
        raise SystemExit("--max-staleness requires --async (or --clock)")
    if getattr(args, "stale_weighting", "uniform") != "uniform" and not async_rounds:
        raise SystemExit("--stale-weighting requires --async (or --clock)")
    weights = periods = speeds = None
    weights_arg = getattr(args, "client_weights", "")
    if weights_arg:
        if kind != "weighted":
            raise SystemExit("--client-weights requires --participation weighted")
        weights = _parse_csv(weights_arg, args.clients, "--client-weights", float)
    periods_arg = getattr(args, "arrival_periods", "")
    if periods_arg:
        if kind != "periodic":
            raise SystemExit("--arrival-periods requires --participation periodic")
        periods = _parse_csv(periods_arg, args.clients, "--arrival-periods", int)
    speeds_arg = getattr(args, "client_speeds", "")
    if speeds_arg:
        if clock_kind == "none":
            raise SystemExit("--client-speeds requires --clock")
        speeds = _parse_csv(speeds_arg, args.clients, "--client-speeds", float)
    compression = getattr(args, "compression", "none")
    error_feedback = getattr(args, "error_feedback", False)
    topk_frac = getattr(args, "topk_frac", None)
    bandwidth = getattr(args, "bandwidth_bps", 0.0)
    if compression != "none" and getattr(args, "no_flat", False):
        raise SystemExit(
            "--compression runs on the flat (m, N) comm buffer and "
            "requires the flat round path (drop --no-flat)")
    if error_feedback and compression == "none":
        raise SystemExit(
            "--error-feedback carries the codec residual — it needs a "
            "lossy --compression (bf16/int8/topk)")
    if topk_frac is not None:
        if compression != "topk":
            raise SystemExit("--topk-frac requires --compression topk")
        if not (0.0 < topk_frac <= 1.0):
            raise SystemExit(
                f"--topk-frac must be in (0, 1], got {topk_frac}")
    if bandwidth:
        if bandwidth < 0:
            raise SystemExit(
                f"--bandwidth-bps must be > 0, got {bandwidth}")
        if clock_kind == "none":
            raise SystemExit(
                "--bandwidth-bps prices the wire inside the wall-clock "
                "simulation — it requires --clock")
    overlap = getattr(args, "overlap", "off")
    if overlap == "scatter" and getattr(args, "no_flat", False):
        raise SystemExit(
            "--overlap scatter carries the reduce-scattered consensus "
            "shard on the flat buffers and requires the flat round path "
            "(drop --no-flat)")
    pod = getattr(args, "pod", 0)
    if pod:
        shard = getattr(args, "shard_clients", 0)
        if shard <= 1:
            raise SystemExit(
                "--pod spans the sharded client axis over a (pod, data) "
                "mesh — it requires --shard-clients")
        if shard % pod:
            raise SystemExit(
                f"--shard-clients ({shard}) must be divisible by "
                f"--pod ({pod}): each pod holds shard_clients/pod devices")
    # --- fault-tolerant rounds (docs/faults.md) --------------------------
    fault_kinds = [k for k in getattr(args, "faults", "").split(",") if k]
    if fault_kinds:
        from repro.core.faults import FAULT_KINDS
        bad = sorted(set(fault_kinds) - set(FAULT_KINDS))
        if bad:
            raise SystemExit(
                f"--faults: unknown kind(s) {','.join(bad)} "
                f"(choose from {','.join(FAULT_KINDS)})")
        if getattr(args, "no_flat", False):
            raise SystemExit(
                "--faults corrupts the flat (m, N) comm buffer and "
                "requires the flat round path (drop --no-flat)")
    rate_arg = getattr(args, "fault_rate", "")
    if rate_arg and not fault_kinds:
        raise SystemExit(
            "--fault-rate is the injection probability of --faults — "
            "pass --faults crash,nan,...")
    fault_rates = [0.05]
    if rate_arg:
        try:
            fault_rates = [float(v) for v in rate_arg.split(",")]
        except ValueError as e:
            raise SystemExit(f"--fault-rate: {e}")
        if len(fault_rates) not in (1, len(fault_kinds)):
            raise SystemExit(
                f"--fault-rate needs 1 or {len(fault_kinds)} values, "
                f"got {len(fault_rates)}")
        if any(not 0.0 <= r <= 1.0 for r in fault_rates):
            raise SystemExit(
                f"--fault-rate values must be in [0, 1], got {rate_arg}")
    screening = getattr(args, "screening", False)
    if screening and getattr(args, "no_flat", False):
        raise SystemExit(
            "--screening filters the flat (m, N) comm buffer and "
            "requires the flat round path (drop --no-flat)")
    clip_norm = getattr(args, "clip_norm", 0.0)
    if clip_norm:
        if clip_norm < 0:
            raise SystemExit(f"--clip-norm must be > 0, got {clip_norm}")
        if not screening:
            raise SystemExit(
                "--clip-norm is the screening stage's norm clip — "
                "pass --screening")
    quorum = getattr(args, "quorum", 0)
    if quorum:
        if not 0 < quorum <= args.clients:
            raise SystemExit(
                f"--quorum must be in [1, m={args.clients}], got {quorum}")
        if kind == "full" and clock_kind == "none" and not fault_kinds \
                and not screening:
            raise SystemExit(
                "--quorum needs a source of non-arrival to guard against "
                "— pass --participation, --clock, --faults or --screening")
    deadline_s = getattr(args, "deadline_s", 0.0)
    if deadline_s:
        if deadline_s < 0:
            raise SystemExit(f"--deadline-s must be > 0, got {deadline_s}")
        if clock_kind == "none":
            raise SystemExit(
                "--deadline-s cuts simulated rounds at a wall-clock "
                "deadline — it requires --clock")
        if quorum < 1:
            raise SystemExit(
                "--deadline-s can close rounds with ZERO arrivals — pass "
                "--quorum (>= 1) so they degrade to recorded no-ops "
                "instead of aggregating nothing")
    watchdog = getattr(args, "watchdog", False)
    patience = getattr(args, "watchdog_patience", None)
    factor = getattr(args, "watchdog_factor", None)
    if not watchdog and (patience is not None or factor is not None):
        raise SystemExit(
            "--watchdog-patience/--watchdog-factor tune the divergence "
            "watchdog — pass --watchdog")
    if watchdog:
        patience = 3 if patience is None else patience
        factor = 2.0 if factor is None else factor
        if patience < 1:
            raise SystemExit(
                f"--watchdog-patience must be >= 1, got {patience}")
        if factor <= 1.0:
            raise SystemExit(
                "--watchdog-factor is a divergence threshold RELATIVE to "
                f"the best f̄ seen and must be > 1, got {factor}")
        if store == "offload":
            raise SystemExit(
                "--watchdog keeps a full state snapshot in the carry — "
                "with --store offload that would double the host-resident "
                "buffers; use --store dense/active")
    ckpt_every = getattr(args, "checkpoint_every", 0)
    resume = getattr(args, "resume", False)
    if ckpt_every < 0:
        raise SystemExit(
            f"--checkpoint-every must be >= 0, got {ckpt_every}")
    if ckpt_every or resume:
        if not getattr(args, "checkpoint_dir", ""):
            raise SystemExit(
                "--checkpoint-every/--resume need --checkpoint-dir to "
                "write/read the round-carry snapshots")
        if getattr(args, "shard_clients", 0) > 1:
            raise SystemExit(
                "checkpointing round-trips the carry through host npz — "
                "it runs unsharded (drop --shard-clients)")
        if chunk == "auto":
            raise SystemExit(
                "--chunk auto re-times candidate chunk lengths — "
                "checkpoint boundaries need a fixed --chunk")
        if getattr(args, "no_scan", False) and store != "offload":
            raise SystemExit(
                "--checkpoint-every/--resume ride the chunked scan "
                "driver (or the offload loop) — drop --no-scan")
    return {
        "kind": kind,
        "clock_kind": clock_kind,
        "async_rounds": async_rounds,
        "weights": weights,
        "periods": periods,
        "speeds": speeds,
        "chunk": chunk,
        "flat": not getattr(args, "no_flat", False),
        "store": store,
        "aggregate": aggregate,
        "use_kernel": use_kernel,
        "kernel_interpret": kernel_interpret,
        "compression": None if compression == "none" else compression,
        "error_feedback": error_feedback,
        "topk_frac": 0.1 if topk_frac is None else topk_frac,
        "bandwidth_bps": bandwidth if bandwidth else None,
        "overlap": overlap,
        "pod": pod,
        "fault_kinds": fault_kinds,
        "fault_rates": fault_rates,
        "screening": screening,
        "clip_norm": clip_norm if clip_norm else None,
        "quorum": quorum,
        "deadline_s": deadline_s if deadline_s else None,
        "watchdog": watchdog,
        "watchdog_patience": 3 if patience is None else patience,
        "watchdog_factor": 2.0 if factor is None else factor,
        "checkpoint_every": ckpt_every,
        "resume": resume,
    }


def train(args) -> dict:
    parsed = validate_flags(args)
    model, loss_fn, params0, batch = build_problem(args)
    fed = FedConfig(
        algorithm=args.algo,
        num_clients=args.clients,
        k0=args.k0,
        alpha=args.alpha,
        sigma_t=args.sigma_t,
        h_policy=args.h_policy,
        collapsed=not args.unrolled,
        lr=args.lr,
        auto_lipschitz=args.arch is not None,
        use_kernel=parsed["use_kernel"],
        kernel_interpret=parsed["kernel_interpret"],
    )
    algo = make_algorithm(fed, loss_fn, model=model)
    state = algo.init(params0, jax.random.PRNGKey(args.seed + 1), init_batch=batch)

    # engine knobs default off so programmatic callers can pass a bare
    # Namespace with only the legacy fields
    shard_clients = getattr(args, "shard_clients", 0)
    mesh = None
    client_axis = "data"
    if shard_clients > 1:
        from repro.launch.mesh import make_host_mesh

        if parsed["pod"]:
            mesh = make_host_mesh(pod=parsed["pod"],
                                  data=shard_clients // parsed["pod"])
            client_axis = ("pod", "data")
            log.info("pod-spanning client axis: %d pods x %d devices",
                     parsed["pod"], shard_clients // parsed["pod"])
        else:
            mesh = make_host_mesh(data=shard_clients)
    if parsed["overlap"] == "scatter":
        log.info("overlapped collectives: eq. (11) split into an early "
                 "reduce-scatter + a deferred consensus all-gather")

    # engine-level participation (core/selection.py): "full" -> None keeps
    # the legacy in-algorithm behaviour (FedGiA's internal §V.B draw)
    kind = parsed["kind"]
    policy = make_policy(
        kind,
        args.clients,
        args.alpha,
        seed=args.seed,
        weights=parsed["weights"],
        drop_prob=getattr(args, "drop_prob", 0.2),
        horizon=max(args.rounds, 1),
        periods=parsed["periods"],
    )
    if policy is not None:
        if kind in ("straggler", "periodic"):
            log.info("participation: %s policy (per-round varying |C|), m=%d",
                     kind, args.clients)
        else:
            log.info("participation: %s policy, alpha=%.2f (|C|=%d of m=%d)",
                     kind, args.alpha, policy.n_selected, args.clients)

    # wall-clock simulation (core/clock.py): the clock derives the arrival
    # mask from simulated finish times and implies async rounds
    clock = make_clock(
        parsed["clock_kind"],
        args.clients,
        compute_s=parsed["speeds"],
        sigma=getattr(args, "clock_sigma", 0.5),
        seed=args.seed,
        bandwidth_bps=parsed["bandwidth_bps"],
        deadline_s=parsed["deadline_s"],
    )
    # fault-tolerant rounds (core/faults.py, docs/faults.md)
    faults = make_faults(
        parsed["fault_kinds"], parsed["fault_rates"],
        num_clients=args.clients, seed=args.seed,
        scale=getattr(args, "fault_scale", 1e6),
    )
    screening = (Screening(clip_norm=parsed["clip_norm"])
                 if parsed["screening"] else None)
    if faults is not None:
        log.info("fault injection: %s at rate(s) %s (on-device, "
                 "stateless per-round keys)",
                 ",".join(parsed["fault_kinds"]),
                 ",".join("%g" % r for r in parsed["fault_rates"]))
    if screening is not None:
        log.info("upload screening: finite check%s riding eq. (11)'s "
                 "collective",
                 (" + norm clip at %g" % parsed["clip_norm"])
                 if parsed["clip_norm"] else "")
    if parsed["quorum"]:
        log.info("quorum: rounds with < %d accepted uploads degrade to "
                 "recorded no-ops", parsed["quorum"])
    if parsed["deadline_s"] is not None:
        log.info("round deadline: %.3g simulated seconds (late clients "
                 "re-arrive next round)", parsed["deadline_s"])
    if parsed["watchdog"]:
        log.info("divergence watchdog: rollback after %d rounds above "
                 "%.2gx the best f̄", parsed["watchdog_patience"],
                 parsed["watchdog_factor"])
    if parsed["checkpoint_every"]:
        log.info("checkpointing the round carry every %d rounds to %s%s",
                 parsed["checkpoint_every"], args.checkpoint_dir,
                 " (resuming)" if parsed["resume"] else "")
    if parsed["compression"] is not None:
        log.info("uplink compression: %s codec%s%s", parsed["compression"],
                 " + error feedback" if parsed["error_feedback"] else "",
                 (" (frac=%.2f)" % parsed["topk_frac"])
                 if parsed["compression"] == "topk" else "")
    if parsed["bandwidth_bps"] is not None:
        log.info("byte-accurate comm clock: %.3g bytes/s per client",
                 parsed["bandwidth_bps"])
    async_rounds = parsed["async_rounds"]
    max_staleness = getattr(args, "max_staleness", 0)
    stale_weighting = getattr(args, "stale_weighting", "uniform")
    if async_rounds:
        if policy is None and clock is None:
            raise SystemExit(
                "--async needs an arrival process: pass --participation "
                "straggler/periodic/... (the mask is who communicates) "
                "or --clock (event-driven wall-clock arrivals)"
            )
        log.info("async rounds: stale-x̄ engine, max_staleness=%d, "
                 "weighting=%s", max_staleness, stale_weighting)
    if clock is not None:
        log.info("wall-clock rounds: %s clock, m=%d", clock.name, args.clients)
    if parsed["store"] == "active":
        cap = args.clients if clock is not None else policy.active_capacity
        log.info("active-set store: (%d, N) participant tile gathered/"
                 "scattered per round (m=%d resident)", cap, args.clients)
    elif parsed["store"] == "offload":
        cap = args.clients if clock is not None else policy.active_capacity
        log.info("host-offloaded store: resident client buffers in host "
                 "memory, (%d, N) tiles shuttled per round (m=%d)",
                 cap, args.clients)
    if parsed["aggregate"] == "packed":
        log.info("packed aggregation: eq. (11) sums the participant tile "
                 "directly (fp tolerance vs the bitwise dense layout)")

    res = run_rounds(
        algo, state, batch, args.rounds,
        tol=args.tol, scan=not getattr(args, "no_scan", False),
        chunk_size=parsed["chunk"], mesh=mesh,
        participation=policy, clock=clock,
        async_rounds=async_rounds, max_staleness=max_staleness,
        stale_weighting=stale_weighting,
        stale_decay=getattr(args, "stale_decay", 1.0),
        flat=parsed["flat"],
        store=parsed["store"],
        aggregate=parsed["aggregate"],
        compression=parsed["compression"],
        error_feedback=parsed["error_feedback"],
        topk_frac=parsed["topk_frac"],
        overlap=parsed["overlap"],
        client_axis=client_axis,
        faults=faults,
        screening=screening,
        quorum=parsed["quorum"],
        watchdog=parsed["watchdog"],
        watchdog_patience=parsed["watchdog_patience"],
        watchdog_factor=parsed["watchdog_factor"],
        checkpoint_every=parsed["checkpoint_every"],
        checkpoint_dir=(args.checkpoint_dir or None)
        if (parsed["checkpoint_every"] or parsed["resume"]) else None,
        resume=parsed["resume"],
    )
    history = [
        {"round": r, "f": float(res.history["f_xbar"][r]),
         "err": float(res.history["grad_sq_norm"][r])}
        for r in range(res.rounds_run)
    ]
    for h in history:
        if h["round"] % args.log_every == 0 or h["round"] == res.rounds_run - 1:
            log.info("round %4d  f=%.6f  |grad|^2=%.3e",
                     h["round"], h["f"], h["err"])
    if res.stopped_early:
        log.info("tolerance reached at round %d", res.rounds_run - 1)
    result = {
        "algo": args.algo,
        "participation": kind,  # the CLI kind, reusable as --participation
        "rounds": res.rounds_run,
        "cr": 2 * res.rounds_run,
        "final_f": history[-1]["f"],
        "final_err": history[-1]["err"],
        "wall_s": res.wall_s,
        "history": history,
    }
    if async_rounds:
        result["max_staleness"] = max_staleness
        result["stale_weighting"] = stale_weighting
        result["staleness_max_seen"] = int(res.history["staleness_max"].max())
        log.info("async: max staleness actually used = %d (bound %d)",
                 result["staleness_max_seen"], max_staleness)
    if parsed["compression"] is not None:
        result["compression"] = parsed["compression"]
        result["error_feedback"] = parsed["error_feedback"]
    if clock is not None:
        result["clock"] = clock.name
        result["sim_time_s"] = float(res.history["sim_time"][-1])
        log.info("simulated wall-clock: %.3f s to round %d "
                 "(time-to-target when the tolerance stopped the run)",
                 result["sim_time_s"], res.rounds_run - 1)
        if parsed["bandwidth_bps"] is not None:
            result["bytes_up"] = float(res.history["bytes_up"].sum())
            result["bytes_down"] = float(res.history["bytes_down"].sum())
            log.info("wire totals: %.0f B up / %.0f B down over %d rounds",
                     result["bytes_up"], result["bytes_down"],
                     res.rounds_run)
    if "screened" in res.history:
        result["screened_min"] = int(res.history["screened"].min())
    if "degraded" in res.history:
        result["degraded_rounds"] = int(res.history["degraded"].sum())
        if result["degraded_rounds"]:
            log.info("%d round(s) missed the quorum and degraded to "
                     "no-ops", result["degraded_rounds"])
    if "rollback" in res.history:
        result["rollbacks"] = int(res.history["rollback"].sum())
        if result["rollbacks"]:
            log.info("watchdog rolled the state back %d time(s)",
                     result["rollbacks"])
    if args.checkpoint_dir and not (parsed["checkpoint_every"]
                                    or parsed["resume"]):
        # legacy final-state save; when the engine owns the directory
        # (--checkpoint-every/--resume) it already persisted the full
        # round carry there and a state-only file would shadow it
        save_checkpoint(args.checkpoint_dir, res.rounds_run, res.state,
                        extra={"algo": args.algo})
        log.info("checkpoint written to %s", args.checkpoint_dir)
    log.info(
        "done: %d rounds (CR=%d) in %.2fs  f=%.6f err=%.2e",
        result["rounds"], result["cr"], res.wall_s, result["final_f"],
        result["final_err"],
    )
    return result


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="linreg",
                    choices=["linreg", "logreg", "ncvx_logreg"])
    ap.add_argument("--arch", choices=list_architectures())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--algo", default="fedgia",
                    choices=["fedgia", "fedavg", "fedprox", "fedpd", "scaffold"])
    ap.add_argument("--clients", type=int, default=128)
    ap.add_argument("--k0", type=int, default=5)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--sigma-t", type=float, default=0.15)
    ap.add_argument("--h-policy", default="scalar",
                    choices=["scalar", "diag_ema", "gram"])
    ap.add_argument("--unrolled", action="store_true")
    ap.add_argument("--no-scan", action="store_true",
                    help="legacy per-round dispatch loop (debugging)")
    ap.add_argument("--chunk", default="0",
                    help="rounds per compiled scan chunk (0 = default "
                         "sizing), or 'auto' to time the candidate chunk "
                         "lengths (8/32/128) on the live run and keep the "
                         "fastest — deterministic results under --tol 0")
    ap.add_argument("--no-flat", action="store_true",
                    help="disable the flat-buffer round path (ravel-once "
                         "(m, N) client state, contiguous eq.-11 "
                         "all-reduce, batched round kernel) and run the "
                         "per-leaf pytree rounds; both paths are bitwise-"
                         "equal on a single device (tests/test_flat.py)")
    ap.add_argument("--kernel", default="auto",
                    choices=["auto", "on", "off", "interpret"],
                    help="route FedGiA's flat collapsed round through the "
                         "batched Pallas fedgia_update kernel: auto "
                         "(kernel on TPU, fused jnp elsewhere), on, off, "
                         "or interpret (Pallas interpret mode — CPU "
                         "validation). Requires the flat path")
    ap.add_argument("--store", default="dense",
                    choices=["dense", "active", "offload"],
                    help="client-state execution strategy for the flat "
                         "path: dense (default, every round's working set "
                         "is (m, N) with non-participants masked out), "
                         "active (each round gathers the participants "
                         "into a packed (capacity, N) tile, runs local "
                         "work at O(capacity) instead of O(m), and "
                         "scatters per-client state back — states bitwise-"
                         "equal to dense, loss/grad diagnostics become "
                         "participant means; the million-client regime, "
                         "see docs/engine.md#active-set-client-store), or "
                         "offload (the active tile loop with the resident "
                         "(m, N) client buffers + batch + stale anchor in "
                         "HOST memory — m bounded by host RAM, bitwise "
                         "equal to active; single device only, see "
                         "docs/engine.md#host-offloaded-store and "
                         "docs/scaling.md). Requires --participation or "
                         "--clock; rejected with --no-flat")
    ap.add_argument("--aggregate", default="dense",
                    choices=["dense", "packed"],
                    help="eq.-(11) aggregation layout for the active/"
                         "offload stores: dense (default — scatter the "
                         "participant tile back to the (m, N) layout "
                         "before reducing, bitwise the dense store) or "
                         "packed (sum the (capacity, N) tile directly — "
                         "O(capacity*N), no dense aggregation temp, ~1 ulp "
                         "fp tolerance; docs/engine.md#packed-aggregation)")
    ap.add_argument("--shard-clients", type=int, default=0,
                    help="shard the client axis over an N-way data mesh")
    ap.add_argument("--pod", type=int, default=0,
                    help="span the sharded client axis over a compound "
                         "(pod, data) mesh: --pod P builds P pods of "
                         "--shard-clients/P devices each and the round's "
                         "collectives run over both axes — bitwise "
                         "identical to the flat data axis. Requires "
                         "--shard-clients divisible by P")
    ap.add_argument("--overlap", default="off", choices=["off", "scatter"],
                    help="overlapped eq.-(11) collectives: off (default — "
                         "the round's one model-size all-reduce, bitwise "
                         "the PR-5 program) or scatter (reduce-scatter "
                         "the round's contribution early, all-gather the "
                         "consensus shard at the top of the NEXT round, so "
                         "the model-size wire hides behind local compute; "
                         "the wall clock credits min(compute, comm) per "
                         "round — docs/engine.md#overlapped-collectives). "
                         "Requires the flat path")
    ap.add_argument("--participation", default="full", choices=POLICIES,
                    help="engine-level per-round client participation: "
                         "full (legacy in-algorithm behaviour), uniform "
                         "(paper §V.B alpha-sampling), weighted "
                         "(sampling weighted by --client-weights), cyclic "
                         "(round-robin blocks), straggler (iid "
                         "availability dropout), periodic (deterministic "
                         "heterogeneous arrival speeds)")
    ap.add_argument("--client-weights", default="",
                    help="comma-separated per-client sampling weights "
                         "(e.g. local data sizes) for --participation "
                         "weighted; default: equal weights")
    ap.add_argument("--drop-prob", type=float, default=0.2,
                    help="per-round client dropout prob (straggler policy)")
    ap.add_argument("--arrival-periods", default="",
                    help="comma-separated per-client arrival periods for "
                         "--participation periodic (client i communicates "
                         "every p_i rounds); default: speeds cycling 1..4")
    ap.add_argument("--async", dest="async_rounds", action="store_true",
                    help="staleness-aware overlapped rounds: the "
                         "participation mask becomes the arrival process "
                         "and stragglers work against their last-"
                         "downloaded x̄ (docs/async.md)")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="bound on the stale-x̄ age in rounds (--async); "
                         "0 = bitwise-identical to the synchronous run")
    ap.add_argument("--clock", default="none", choices=("none",) + CLOCKS,
                    help="wall-clock simulation (implies --async): derive "
                         "the arrival mask from per-client compute times "
                         "instead of a sampled policy — constant "
                         "(fixed per-client speeds), lognormal (jittered), "
                         "trace (library-level; needs a duration table). "
                         "Reports simulated seconds alongside CR")
    ap.add_argument("--client-speeds", default="",
                    help="comma-separated per-client compute seconds for "
                         "--clock (default: speeds cycling 1..4, the "
                         "wall-clock twin of the periodic policy)")
    ap.add_argument("--clock-sigma", type=float, default=0.5,
                    help="lognormal compute-time jitter for --clock "
                         "lognormal")
    ap.add_argument("--stale-weighting", default="uniform",
                    choices=["uniform", "poly", "exp"],
                    help="staleness-aware aggregation (--async/--clock): "
                         "downweight a contribution computed against an "
                         "s-rounds-old anchor — uniform (unweighted, "
                         "bitwise today's path), poly ((1+s)^-decay), "
                         "exp (e^(-decay*s))")
    ap.add_argument("--stale-decay", type=float, default=1.0,
                    help="decay rate for --stale-weighting poly/exp")
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8", "topk"],
                    help="uplink codec on the flat comm buffer "
                         "(core/compress.py): none (bitwise identity — "
                         "the uncompressed engine), bf16 (2 B/lane), int8 "
                         "(per-client affine, stochastic rounding, ~1 "
                         "B/lane), topk (keep the --topk-frac largest-|.| "
                         "lanes). Decompress-before-reduce: the round "
                         "keeps its one model-size all-reduce. Requires "
                         "the flat path")
    ap.add_argument("--topk-frac", type=float, default=None,
                    help="fraction of lanes kept by --compression topk "
                         "(default 0.1)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry per-client error-feedback residuals (one "
                         "extra (m, N) flat buffer in the scan carry): "
                         "each upload adds the previous rounds' codec "
                         "error back in, so the compression error "
                         "telescopes instead of accumulating. Requires a "
                         "lossy --compression")
    ap.add_argument("--bandwidth-bps", type=float, default=0.0,
                    help="per-client uplink/downlink bandwidth in bytes/s "
                         "for --clock: comm time becomes BYTE-ACCURATE "
                         "(the codec's exact wire size per round, "
                         "core/compress.py) and the run reports "
                         "bytes_up/bytes_down; 0 keeps the constant "
                         "comm-time model bitwise")
    ap.add_argument("--faults", default="",
                    help="comma-separated fault kinds injected into the "
                         "decoded uplink ON DEVICE each round "
                         "(core/faults.py, drawn from stateless per-round "
                         "keys — deterministic across scan/legacy, stores, "
                         "shardings and checkpoint resume): crash (drop "
                         "the upload), nan / inf (corrupt a prefix of the "
                         "row), explode (scale the update by "
                         "--fault-scale), replay (re-send the previous "
                         "round's upload). Requires the flat path")
    ap.add_argument("--fault-rate", default="",
                    help="per-client per-round injection probability for "
                         "--faults: one value broadcast over all kinds, "
                         "or one per kind (comma-separated); default 0.05")
    ap.add_argument("--fault-scale", type=float, default=1e6,
                    help="magnitude multiplier for the explode fault")
    ap.add_argument("--screening", action="store_true",
                    help="defensive server-side screening of the decoded "
                         "uploads (api.harden_upload): rows with any "
                         "non-finite entry are dropped from the "
                         "aggregation mask before eq. (11)'s reduction — "
                         "the round keeps its ONE model-size all-reduce "
                         "(the finite check rides the same collective). "
                         "Useful without --faults too (real NaN guards)")
    ap.add_argument("--clip-norm", type=float, default=0.0,
                    help="screening norm clip: finite rows with l2 norm "
                         "above this are scaled onto the clip ball "
                         "(defuses explode faults). Requires --screening")
    ap.add_argument("--quorum", type=int, default=0,
                    help="minimum accepted-upload count for a round to "
                         "commit: an under-quorum round becomes a recorded "
                         "no-op (x̄ carried, history row flagged "
                         "degraded=1). Requires a source of non-arrival "
                         "(--participation, --clock, --faults or "
                         "--screening); required >= 1 with --deadline-s")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="wall-clock round deadline for --clock: each "
                         "round closes after this many simulated seconds "
                         "and only clients that finished participate "
                         "(late clients re-arrive next round). Zero-"
                         "arrival rounds degrade under --quorum")
    ap.add_argument("--watchdog", action="store_true",
                    help="divergence watchdog: track the best f̄ seen and "
                         "a state snapshot in the carry; after "
                         "--watchdog-patience consecutive rounds with "
                         "f̄ > --watchdog-factor x best (NaN counts as "
                         "diverged) restore the snapshot and flag "
                         "rollback=1 in the history. Doubles the carry "
                         "state; not available with --store offload")
    ap.add_argument("--watchdog-patience", type=int, default=None,
                    help="diverged rounds tolerated before the rollback "
                         "(default 3). Requires --watchdog")
    ap.add_argument("--watchdog-factor", type=float, default=None,
                    help="divergence threshold relative to the best f̄ "
                         "seen (default 2.0, must be > 1). Requires "
                         "--watchdog")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot the FULL round carry (client buffers, "
                         "policy/clock/staleness/watchdog state, rng, "
                         "metric history) to --checkpoint-dir every this "
                         "many rounds (atomic npz, checkpoint/); a "
                         "--resume run restores the newest snapshot and "
                         "is BITWISE identical to the uninterrupted run. "
                         "Scan and offload paths; needs a fixed --chunk "
                         "and no --shard-clients")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest checkpoint under "
                         "--checkpoint-dir (fresh start when none "
                         "exists); the run config must hash-match the "
                         "checkpointing run")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--tol", type=float, default=1e-7)
    ap.add_argument("--dim", type=int, default=100)
    ap.add_argument("--samples", type=int, default=12800)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default="")
    return ap


def main():
    train(build_parser().parse_args())


if __name__ == "__main__":
    main()
