"""Roofline-term extraction from compiled XLA artifacts.

`compiled.cost_analysis()` gives PER-DEVICE HLO flops / bytes accessed.
Collective traffic is NOT in cost_analysis: we parse the (post-SPMD,
per-device) HLO text and sum the result sizes of every collective op,
weighting all-reduce by 2x (ring reduce-scatter + all-gather wire cost).

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# wire-cost multiplier per result byte (ring algorithms)
_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result sizes of collective ops in a per-device HLO module.

    Async pairs (-start/-done) are counted once (the -start op).
    Returns {op_kind: bytes, "total": bytes, "wire_bytes": weighted}."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    wire = 0.0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"=\s*(.+?)\s+(%?)([\w-]+)\(", line)
        if not m:
            continue
        op = m.group(3)
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        lhs = line.split(f" {op}(")[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
        out[base] += nbytes
        wire += nbytes * _WIRE_FACTOR[base]
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["wire_bytes"] = wire
    return out


def roofline_terms(cost: dict, coll: Dict[str, float]) -> Dict[str, float]:
    """Three roofline terms (seconds, per chip) + dominance."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_coll = coll["wire_bytes"] / ICI_BW
    terms = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
    }
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = {
        "t_compute_s": "compute",
        "t_memory_s": "memory",
        "t_collective_s": "collective",
    }[dom]
    terms["hlo_flops"] = flops
    terms["hlo_bytes"] = bytes_hbm
    terms["collective_bytes"] = coll["total"]
    terms["wire_bytes"] = coll["wire_bytes"]
    return terms


def count_hlo_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}(?:\.\d+)?\(", hlo_text))
