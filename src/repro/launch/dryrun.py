import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent for every
(architecture x input shape x mesh) with abstract lowering + compile only.

  train_4k     -> one FedGiA communication round (the paper's algorithm) —
                  or a baseline's round via --algo
  prefill_32k  -> serve_step prefill (builds the KV cache)
  decode_32k   -> serve_step decode: ONE token against a 32k cache
  long_500k    -> decode with 512k context: recurrent state (ssm/hybrid) or
                  sliding-window ring cache (all attention archs)

For each combination we print/record compiled.memory_analysis() (fits?),
compiled.cost_analysis() (per-chip FLOPs/bytes) and the collective traffic
parsed from the per-device HLO — the inputs to EXPERIMENTS.md §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--algo fedgia|fedavg] [--unrolled]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import FedConfig, INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.configs import get_config, list_architectures
from repro.core import make_algorithm
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models import Transformer
from repro.models.attention import AttnMode


# --------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig, num_clients: int = 0):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        m = num_clients
        bc = max(B // m, 1)
        if cfg.input_mode == "tokens":
            return {"tokens": jax.ShapeDtypeStruct((m, bc, S + 1), tok)}
        if cfg.input_mode == "embeds":
            return {
                "embeds": jax.ShapeDtypeStruct((m, bc, S, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((m, bc, S), tok),
            }
        P_img = cfg.embed_prefix_len
        return {
            "embeds": jax.ShapeDtypeStruct((m, bc, P_img, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((m, bc, S - P_img + 1), tok),
        }
    if shape.kind == "prefill":
        if cfg.input_mode == "embeds":
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        if cfg.input_mode == "tokens+embeds":
            P_img = cfg.embed_prefix_len
            return {
                "embeds": jax.ShapeDtypeStruct((B, P_img, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S - P_img), tok),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
    # decode: ONE new token; the cache IS the context
    return {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}


def _cache_len(cfg: ModelConfig, shape: ShapeConfig):
    if shape.name == "long_500k":
        return min(cfg.sliding_window, shape.seq_len)
    return shape.seq_len


def _decode_window(cfg: ModelConfig, shape: ShapeConfig):
    return cfg.sliding_window if shape.name == "long_500k" else None


# ------------------------------------------------------------------ builders
def build_train(cfg, shape, fed: FedConfig, mesh, algo_name="fedgia"):
    from repro.sharding import (
        fed_state_specs,
        param_specs,
        sanitize_specs,
        train_batch_specs,
    )

    model = Transformer(cfg)
    fed = dataclasses.replace(fed, algorithm=algo_name)
    algo = make_algorithm(fed, model.loss, model=model)

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    rng_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    state_sds = jax.eval_shape(algo.init, params_sds, rng_sds)
    batch_sds = input_specs(cfg, shape, fed.num_clients)

    state_specs = sanitize_specs(fed_state_specs(fed, cfg, state_sds), state_sds, mesh)
    batch_specs = sanitize_specs(
        train_batch_specs(fed, batch_sds, mesh.axis_names), batch_sds, mesh
    )

    shard = lambda specs: jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    metrics_sds = jax.eval_shape(algo.round, state_sds, batch_sds)[1]
    metrics_specs = jax.tree.map(lambda _: P(), metrics_sds)

    fn = jax.jit(
        algo.round,
        in_shardings=(shard(state_specs), shard(batch_specs)),
        out_shardings=(shard(state_specs), shard(metrics_specs)),
    )
    return fn, (state_sds, batch_sds)


def build_prefill(cfg, shape, mesh):
    from repro.sharding import cache_specs, param_specs, sanitize_specs

    model = Transformer(cfg)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    W = _cache_len(cfg, shape)
    B = shape.global_batch

    def prefill_step(params, batch):
        return model.prefill(params, cache_len=W, **batch)

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch_sds = input_specs(cfg, shape)
    pspecs = sanitize_specs(param_specs(cfg, params_sds), params_sds, mesh)
    bspec = jax.tree.map(
        lambda s: P(
            (tuple(data_axes) if len(data_axes) > 1 else data_axes[0])
            if B > 1 else None,
            *([None] * (len(s.shape) - 1)),
        ),
        batch_sds,
    )
    logits_sds, cache_sds = jax.eval_shape(prefill_step, params_sds, batch_sds)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    cspec = sanitize_specs(
        cache_specs(cfg, cache_sds, B, data_axes, model_size=msize),
        cache_sds, mesh,
    )
    bspec = sanitize_specs(bspec, batch_sds, mesh)
    shard = lambda specs: jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    fn = jax.jit(
        prefill_step,
        in_shardings=(shard(pspecs), shard(bspec)),
        out_shardings=(None, shard(cspec)),
    )
    return fn, (params_sds, batch_sds)


def build_decode(cfg, shape, mesh, cache_dtype=jnp.bfloat16):
    from repro.sharding import cache_specs, param_specs, sanitize_specs

    model = Transformer(cfg)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    W = _cache_len(cfg, shape)
    B = shape.global_batch
    window = _decode_window(cfg, shape)

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, window=window)

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, W, cache_dtype)
    )
    tok_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    pspecs = sanitize_specs(param_specs(cfg, params_sds), params_sds, mesh)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    cspec = sanitize_specs(
        cache_specs(cfg, cache_sds, B, data_axes, model_size=msize),
        cache_sds, mesh,
    )
    tspec = P(
        (tuple(data_axes) if len(data_axes) > 1 else data_axes[0]) if B > 1 else None,
        None,
    )
    shard = lambda specs: jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    fn = jax.jit(
        decode_step,
        in_shardings=(
            shard(pspecs),
            shard(cspec),
            NamedSharding(mesh, tspec),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(None, shard(cspec)),
    )
    return fn, (params_sds, cache_sds, tok_sds, pos_sds)


# ----------------------------------------------------- cost extrapolation
# XLA cost_analysis counts lax.scan bodies ONCE (trip counts are not
# multiplied), so the production scan-over-layers lowering under-reports
# FLOPs/bytes/collectives by ~L. The cost pass lowers small UNROLLED
# variants (scan_layers=False: python-loop layers + unrolled attention
# blocks) with 1 and 2 layers per group and extrapolates:
#   total = f(base) + sum_g (L_g - 1) * [f(base + e_g) - f(base)]
# Sequential time recurrences (rwkv6/ssm) cannot be unrolled (T up to 32k);
# their per-step cost is counted once per layer and corrected analytically.
def _group_counts(cfg):
    from repro.models.transformer import _layer_groups

    return {g.name: g.count for g in _layer_groups(cfg)}


def _small_cfg(cfg, counts):
    total = sum(counts.values())
    changes = dict(num_layers=total, scan_layers=False, remat=False)
    if cfg.moe and cfg.first_dense_layers:
        changes["first_dense_layers"] = counts.get("dense", 0)
    return dataclasses.replace(cfg, **changes)


def _lower_costs(cfg_small, shape, fed, mesh, algo_name,
                 cache_dtype=jnp.bfloat16):
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            fn, args = build_train(cfg_small, shape, fed, mesh, algo_name=algo_name)
        elif shape.kind == "prefill":
            fn, args = build_prefill(cfg_small, shape, mesh)
        else:
            fn, args = build_decode(cfg_small, shape, mesh, cache_dtype=cache_dtype)
        compiled = fn.lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_total": coll["total"],
        "coll_wire": coll["wire_bytes"],
    }


def _recurrence_correction(cfg, shape, num_clients, num_devices):
    """Per-device analytic correction for sequential time-scans: the HLO
    counts ONE timestep per layer; add the remaining (T-1) steps."""
    if cfg.attention_type not in ("rwkv", "hybrid"):
        return {}
    if shape.kind == "train":
        T = shape.seq_len
        B = shape.global_batch
        bwd_factor = 3.0  # fwd + ~2x bwd
    elif shape.kind == "prefill":
        T, B, bwd_factor = shape.seq_len, shape.global_batch, 1.0
    else:
        return {}  # decode: T=1, nothing missing
    L = cfg.num_layers
    if cfg.attention_type == "rwkv":
        hd = cfg.rwkv_head_size
        step_flops = 10.0 * B * cfg.num_heads * hd * hd
        step_bytes = 4.0 * B * cfg.num_heads * hd * hd * 4  # state r/w fp32
    else:  # hybrid mamba branch
        step_flops = 8.0 * B * cfg.d_model * cfg.ssm_state
        step_bytes = 4.0 * B * cfg.d_model * cfg.ssm_state * 4
    corr = {
        "flops": L * (T - 1) * step_flops * bwd_factor / num_devices,
        "bytes": L * (T - 1) * step_bytes * bwd_factor / num_devices,
        "coll_total": 0.0,
        "coll_wire": 0.0,
    }
    return corr


def extrapolated_costs(cfg, shape, fed, mesh, algo_name, num_clients,
                       cache_dtype=jnp.bfloat16):
    counts_full = _group_counts(cfg)
    base = {name: 1 for name in counts_full}
    f_base = _lower_costs(_small_cfg(cfg, base), shape, fed, mesh, algo_name,
                          cache_dtype=cache_dtype)
    totals = dict(f_base)
    for name, L in counts_full.items():
        if L <= 1:
            continue
        plus = dict(base)
        plus[name] += 1
        f_plus = _lower_costs(_small_cfg(cfg, plus), shape, fed, mesh,
                              algo_name, cache_dtype=cache_dtype)
        for k in totals:
            body = max(f_plus[k] - f_base[k], 0.0)
            totals[k] += (L - 1) * body
    corr = _recurrence_correction(cfg, shape, num_clients, mesh.devices.size)
    for k, v in corr.items():
        totals[k] = totals.get(k, 0.0) + v
    return totals


# ------------------------------------------------------------------- dry run
def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               algo: str = "fedgia", collapsed: bool = True,
               num_clients: int = 0, verbose: bool = True,
               with_costs: bool = True, client_axes=None,
               fsdp: bool = False, replicate_params: bool = False,
               cache_dtype="bfloat16"):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    if client_axes is None:
        client_axes = ("pod", "data") if multi_pod else ("data",)
    if num_clients == 0:
        num_clients = 1
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for a in client_axes:
            num_clients *= sizes[a]
    # FSDP shards client states over the leftover data axes; with
    # replicate_params (no TP) the model axis is free for state sharding
    # too — the elementwise FedGiA update is sharding-agnostic.
    fsdp_axes = tuple(
        a for a in mesh.axis_names
        if a not in client_axes and (a != "model" or replicate_params)
    ) if fsdp else ()
    fed = FedConfig(
        algorithm=algo,
        num_clients=num_clients,
        k0=5,
        alpha=0.5,
        collapsed=collapsed,
        h_policy="scalar",
        client_axes=tuple(client_axes),
        fsdp_axes=fsdp_axes,
        replicate_params=replicate_params,
        state_dtype="bfloat16",
    )

    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            fn, args = build_train(cfg, shape, fed, mesh, algo_name=algo)
        elif shape.kind == "prefill":
            fn, args = build_prefill(cfg, shape, mesh)
        else:
            fn, args = build_decode(cfg, shape, mesh,
                                    cache_dtype=jnp.dtype(cache_dtype))
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    if with_costs:
        # scan-corrected per-device costs via unrolled-small extrapolation
        ext = extrapolated_costs(cfg, shape, fed, mesh, algo, num_clients,
                                 cache_dtype=jnp.dtype(cache_dtype))
        cost = {"flops": ext["flops"], "bytes accessed": ext["bytes"]}
        coll = dict(coll)
        coll["total"] = ext["coll_total"]
        coll["wire_bytes"] = ext["coll_wire"]
    terms = roofline_terms(cost, coll)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "algo": algo if shape.kind == "train" else "serve",
        "collapsed": collapsed,
        "client_axes": list(client_axes),
        "fsdp": fsdp,
        "replicate_params": replicate_params,
        "num_clients": num_clients if shape.kind == "train" else 0,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "per_device": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "flops": terms["hlo_flops"],
            "hbm_bytes": terms["hlo_bytes"],
        },
        "collectives": {k: v for k, v in coll.items()},
        "roofline": {
            k: terms[k]
            for k in ("t_compute_s", "t_memory_s", "t_collective_s", "bottleneck")
        },
    }
    if verbose:
        fit_gb = (
            ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
        ) / 2**30
        print(
            f"[dryrun] {arch} {shape_name} mesh={rec['mesh']} algo={rec['algo']}"
            f" lower={t_lower:.1f}s compile={t_compile:.1f}s"
        )
        print(
            f"  per-chip: args+out+temp={fit_gb:.2f} GiB"
            f" flops={terms['hlo_flops']:.3e} hbm={terms['hlo_bytes']:.3e}"
            f" coll={coll['total']:.3e}B"
        )
        print(
            f"  roofline: compute={terms['t_compute_s']*1e3:.3f}ms"
            f" memory={terms['t_memory_s']*1e3:.3f}ms"
            f" collective={terms['t_collective_s']*1e3:.3f}ms"
            f" -> {terms['bottleneck']}-bound"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_architectures())
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--algo", default="fedgia")
    ap.add_argument("--unrolled", action="store_true",
                    help="paper-faithful unrolled k0-step ADMM (vs collapsed)")
    ap.add_argument("--num-clients", type=int, default=0)
    ap.add_argument("--no-costs", action="store_true",
                    help="skip the unrolled cost-extrapolation pass")
    ap.add_argument("--client-axes", default="",
                    help="comma-sep mesh axes enumerating clients (e.g. pod)")
    ap.add_argument("--fsdp", action="store_true",
                    help="shard client states over the leftover data axes")
    ap.add_argument("--replicate-params", action="store_true",
                    help="pure DP within clients (no tensor parallelism)")
    ap.add_argument("--cache-dtype", default="bfloat16",
                    help="KV-cache dtype for decode shapes (e.g. float8_e4m3fn)")
    ap.add_argument("--tag", default="", help="suffix for the output file")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = (
        [(a, s) for a in list_architectures() for s in INPUT_SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape in combos:
        tag = f"{arch}_{shape}_{'2pod' if args.multi_pod else '1pod'}_{args.algo}" + (
            "_unrolled" if args.unrolled else ""
        ) + (f"_{args.tag}" if args.tag else "")
        path = os.path.join(args.out, tag + ".json")
        try:
            rec = dryrun_one(
                arch, shape, multi_pod=args.multi_pod, algo=args.algo,
                collapsed=not args.unrolled, num_clients=args.num_clients,
                with_costs=not args.no_costs,
                client_axes=(tuple(args.client_axes.split(","))
                             if args.client_axes else None),
                fsdp=args.fsdp, replicate_params=args.replicate_params,
                cache_dtype=args.cache_dtype,
            )
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        except Exception as e:  # noqa: BLE001 — record and continue
            failures.append((arch, shape, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nall {len(combos)} dry-runs compiled OK")


if __name__ == "__main__":
    main()
