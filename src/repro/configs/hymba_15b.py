"""Hymba 1.5B — hybrid-head: attention heads and Mamba(SSM) heads run in
PARALLEL inside every block and their outputs are fused. [arXiv:2411.13676]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attention_type="hybrid",
    ssm_state=16,
    source="arXiv:2411.13676",
)
