"""MusicGen-large — decoder-only transformer over EnCodec tokens.
The EnCodec conv codec is the modality frontend and is STUBBED:
input_specs provides precomputed frame embeddings (B, S, d_model);
labels are EnCodec codebook tokens (vocab 2048). [arXiv:2306.05284]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    input_mode="embeds",
    source="arXiv:2306.05284",
)
