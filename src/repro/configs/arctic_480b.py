"""Snowflake Arctic 480B — dense-MoE hybrid: every block has a dense
residual MLP in PARALLEL with a 128-expert top-2 MoE.
[hf:Snowflake/snowflake-arctic-base]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=True,
    num_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,
    router_aux_coef=0.01,
    source="hf:Snowflake/snowflake-arctic-base",
)
