"""RWKV-6 "Finch" 3B — attention-free RNN with data-dependent decay.
[arXiv:2404.05892]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # 2560 / head_size 64
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attention_type="rwkv",
    rwkv_head_size=64,
    source="arXiv:2404.05892",
)
