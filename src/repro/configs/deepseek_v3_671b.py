"""DeepSeek-V3 671B — MLA attention, 1 shared + 256 routed experts (top-8),
first 3 layers dense, multi-token-prediction aux head. [arXiv:2412.19437]

moe_d_ff=2048 per assignment; the leading dense layers use the model-card
dense FFN width 18432.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,  # dense layers (model card); experts use moe_d_ff
    vocab_size=129280,
    moe=True,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    router_aux_coef=0.001,
    attention_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    mtp=True,
    source="arXiv:2412.19437",
)
