"""Architecture registry: one module per assigned architecture.

Usage:  from repro.configs import get_config;  cfg = get_config("tinyllama-1.1b")
"""
from __future__ import annotations

from repro.config import ModelConfig

from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.qwen15_05b import CONFIG as _qwen
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.tinyllama_11b import CONFIG as _tinyllama
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.deepseek_67b import CONFIG as _ds67
from repro.configs.hymba_15b import CONFIG as _hymba
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3

ARCHITECTURES = {
    c.name: c
    for c in [
        _arctic,
        _rwkv6,
        _qwen,
        _stablelm,
        _musicgen,
        _tinyllama,
        _llava,
        _ds67,
        _hymba,
        _dsv3,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}"
        )
    return ARCHITECTURES[name]


def list_architectures():
    return sorted(ARCHITECTURES)
