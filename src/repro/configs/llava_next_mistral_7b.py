"""LLaVA-NeXT (Mistral-7B backbone) — VLM with anyres tiling.
The SigLIP/CLIP vision tower + projector is the modality frontend and is
STUBBED: input_specs provides precomputed patch embeddings for
embed_prefix_len image tokens (anyres: 5 tiles x 576 patches = 2880),
followed by text tokens. [hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    input_mode="tokens+embeds",
    embed_prefix_len=2880,  # anyres: 5 tiles x 24x24 patches
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
