"""Selective SSM (Mamba-style) branch used by the Hymba hybrid block.

Simplified faithful core: data-dependent (dt, B, C) selective scan with
diagonal A, gated output. Inner dim = d_model (Hymba pairs each attention
head with an SSM head of the same width). No depthwise conv (noted in
DESIGN.md as a simplification).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import he_init, silu


def ssm_init(rng, cfg: ModelConfig, dtype):
    d, st = cfg.d_model, cfg.ssm_state
    ks = jax.random.split(rng, 6)
    return {
        "in_x": he_init(ks[0], (d, d), d, dtype),
        "in_z": he_init(ks[1], (d, d), d, dtype),
        "w_dt": he_init(ks[2], (d, d), d, dtype),
        "dt_bias": jnp.full((d,), -2.0, dtype),
        "w_B": he_init(ks[3], (d, st), d, dtype),
        "w_C": he_init(ks[4], (d, st), d, dtype),
        "A_log": jnp.zeros((d, st), jnp.float32),
        "D": jnp.ones((d,), dtype),
        "out": he_init(ks[5], (d, d), d, dtype),
    }


def ssm_scan(u, dt, Bm, Cm, A, state0):
    """u,dt: (B,T,di); Bm,Cm: (B,T,st); A: (di,st); state0: (B,di,st).

    h_t = exp(dt_t * A) h_{t-1} + (dt_t * u_t) B_t ;  y_t = <h_t, C_t> + D u_t
    """

    def step(h, xs):
        ut, dtt, bt, ct = xs  # (B,di) (B,di) (B,st) (B,st)
        decay = jnp.exp(dtt[..., None] * A)  # (B,di,st)
        h_new = decay * h + (dtt * ut)[..., None] * bt[:, None, :]
        y = jnp.einsum("bds,bs->bd", h_new, ct)
        return h_new, y

    xs = jax.tree.map(lambda a: a.swapaxes(0, 1), (u, dt, Bm, Cm))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), state


def ssm_apply(params, cfg: ModelConfig, x, ssm_state):
    """x: (B,T,d); ssm_state: (B,d,st). Returns (out, new_state)."""
    u = jnp.einsum("btd,de->bte", x, params["in_x"])
    z = jnp.einsum("btd,de->bte", x, params["in_z"])
    dt = jax.nn.softplus(
        jnp.einsum("btd,de->bte", x, params["w_dt"]) + params["dt_bias"]
    ).astype(jnp.float32)
    Bm = jnp.einsum("btd,ds->bts", x, params["w_B"]).astype(jnp.float32)
    Cm = jnp.einsum("btd,ds->bts", x, params["w_C"]).astype(jnp.float32)
    A = -jnp.exp(params["A_log"])
    y, new_state = ssm_scan(u.astype(jnp.float32), dt, Bm, Cm, A, ssm_state)
    y = y.astype(x.dtype) + params["D"] * u
    out = jnp.einsum("btd,de->bte", y * silu(z), params["out"])
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int):
    return jnp.zeros((batch, cfg.d_model, cfg.ssm_state), jnp.float32)
