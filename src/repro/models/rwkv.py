"""RWKV-6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Attention-free: the sequence mixer is a linear recurrence over a per-head
(head_dim x head_dim) state — O(1) decode state, sub-quadratic everywhere,
so rwkv6 runs long_500k natively.  The lax.scan here is the oracle for the
chunked Pallas kernel in repro/kernels/rwkv6_scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import he_init, rmsnorm_nohead, silu

DECAY_LORA = 64


def time_mix_init(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H, hd = cfg.num_heads, cfg.rwkv_head_size
    ks = jax.random.split(rng, 8)
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),  # r,k,v,w,g token-shift lerps
        "wr": he_init(ks[0], (d, H * hd), d, dtype),
        "wk": he_init(ks[1], (d, H * hd), d, dtype),
        "wv": he_init(ks[2], (d, H * hd), d, dtype),
        "wg": he_init(ks[3], (d, H * hd), d, dtype),
        "wo": he_init(ks[4], (H * hd, d), H * hd, dtype),
        "decay_w1": he_init(ks[5], (d, DECAY_LORA), d, dtype),
        "decay_w2": he_init(ks[6], (DECAY_LORA, d), DECAY_LORA, dtype),
        "decay_bias": jnp.full((d,), -4.0, dtype),
        "bonus_u": he_init(ks[7], (H, hd), hd, dtype),
    }


def channel_mix_init(rng, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "mu_k": 0.5 * jnp.ones((d,), dtype),
        "mu_r": 0.5 * jnp.ones((d,), dtype),
        "wk": he_init(ks[0], (d, f), d, dtype),
        "wv": he_init(ks[1], (f, d), f, dtype),
        "wr": he_init(ks[2], (d, d), d, dtype),
    }


def _token_shift(x, shift_state):
    """x: (B,T,d); shift_state: (B,d) = last token of the previous chunk."""
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def wkv6_scan(r, k, v, w, u, state0):
    """RWKV6 recurrence (oracle).

    r,k,v,w: (B,T,H,hd); u: (H,hd); state0: (B,H,hd,hd) [key_dim, value_dim].
      y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns y: (B,T,H,hd), final state.
    """

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hdk,hdv)
        y = jnp.einsum("bhj,bhji->bhi", rt, S + u[..., None] * kv)
        S_new = wt[..., None] * S + kv
        return S_new, y

    xs = jax.tree.map(lambda a: a.swapaxes(0, 1), (r, k, v, w))  # (T,B,H,hd)
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1), state  # (B,T,H,hd)


def time_mix_apply(params, cfg: ModelConfig, x, tm_state):
    """tm_state: {"shift": (B,d), "wkv": (B,H,hdk,hdv)} or zeros for train."""
    B, T, d = x.shape
    H, hd = cfg.num_heads, cfg.rwkv_head_size
    prev = _token_shift(x, tm_state["shift"])
    mu = params["mu"]
    xr, xk, xv, xw, xg = [x + mu[i] * (prev - x) for i in range(5)]
    r = jnp.einsum("btd,de->bte", xr, params["wr"]).reshape(B, T, H, hd)
    k = jnp.einsum("btd,de->bte", xk, params["wk"]).reshape(B, T, H, hd)
    v = jnp.einsum("btd,de->bte", xv, params["wv"]).reshape(B, T, H, hd)
    g = silu(jnp.einsum("btd,de->bte", xg, params["wg"])).reshape(B, T, H, hd)
    # data-dependent decay (the Finch signature)
    decay = params["decay_bias"] + jnp.einsum(
        "btd,dl,le->bte", jnp.tanh(xw), params["decay_w1"], params["decay_w2"]
    )
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32))).reshape(B, T, H, hd)

    y, wkv_new = wkv6_scan(
        r.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        w,
        params["bonus_u"].astype(jnp.float32),
        tm_state["wkv"],
    )
    y = rmsnorm_nohead(y, eps=1e-5).astype(x.dtype)  # per-head group norm
    y = (y * g).reshape(B, T, H * hd)
    out = jnp.einsum("bte,ed->btd", y, params["wo"])
    new_state = {"shift": x[:, -1, :], "wkv": wkv_new}
    return out, new_state


def channel_mix_apply(params, x, cm_shift):
    prev = _token_shift(x, cm_shift)
    xk = x + params["mu_k"] * (prev - x)
    xr = x + params["mu_r"] * (prev - x)
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, params["wk"])))
    kv = jnp.einsum("btf,fd->btd", k, params["wv"])
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, params["wr"])) * kv
    return out, x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype):
    H, hd = cfg.num_heads, cfg.rwkv_head_size
    return {
        "shift": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "cm_shift": jnp.zeros((batch, cfg.d_model), dtype),
    }
