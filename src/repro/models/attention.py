"""Attention: GQA (llama-style, optional QKV bias / sliding window) and MLA
(DeepSeek-V3 latent attention, absorbed decode path).

The softmax is computed with the *blocked streaming* (flash) algorithm in
pure jnp — numerically identical to full softmax, O(S * block_k) memory.
This is both the production lowering used by the dry-run and the oracle for
the Pallas flash kernel in repro/kernels/flash_attention.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import apply_rope, he_init

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnMode:
    kind: str = "train"  # train | prefill | decode
    window: Optional[int] = None  # sliding-window mask width (None = full)
    block_k: int = 512


# ============================================================ blocked softmax
def blocked_attention(q, k, v, q_positions, kv_positions, *, window=None,
                      block_k=512, scale=None, unroll=False):
    """Streaming-softmax attention.

    q: (B, S, H, dqk); k: (B, T, Kv, dqk); v: (B, T, Kv, dv)
    q_positions: (S,) int32 absolute positions of queries
    kv_positions: (T,) int32 absolute positions of keys (-1 = invalid slot)
    Causal: key visible iff 0 <= kv_pos <= q_pos (and q_pos - kv_pos < window).
    Returns (B, S, H, dv).
    """
    B, S, H, dqk = q.shape
    T, Kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // Kv
    scale = scale if scale is not None else 1.0 / (dqk**0.5)

    qr = q.reshape(B, S, Kv, G, dqk).transpose(0, 2, 3, 1, 4)  # B,Kv,G,S,dqk
    qr = (qr * scale).astype(q.dtype)

    block_k = min(block_k, T)
    nb = -(-T // block_k)
    pad = nb * block_k - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    kb = k.reshape(B, nb, block_k, Kv, dqk).transpose(1, 0, 3, 2, 4)  # nb,B,Kv,bk,d
    vb = v.reshape(B, nb, block_k, Kv, dv).transpose(1, 0, 3, 2, 4)
    pb = kv_positions.reshape(nb, block_k)

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, posblk = xs
        s = jnp.einsum(
            "bkgsd,bktd->bkgst", qr.astype(jnp.float32), kblk.astype(jnp.float32)
        )  # B,Kv,G,S,bk
        valid = (posblk[None, :] <= q_positions[:, None]) & (posblk[None, :] >= 0)
        if window is not None:
            valid &= q_positions[:, None] - posblk[None, :] < window
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,bktd->bkgsd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Kv, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, G, S), jnp.float32)
    acc0 = jnp.zeros((B, Kv, G, S, dv), jnp.float32)
    if unroll:
        # straight-line variant for the dry-run cost pass: XLA cost_analysis
        # counts scan bodies once, so the streaming loop must be unrolled
        # for faithful FLOP/byte accounting.
        carry = (m0, l0, acc0)
        for i in range(nb):
            carry, _ = step(carry, (kb[i], vb[i], pb[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, dv)
    return out.astype(q.dtype)


# ===================================================================== GQA
def gqa_init(rng, cfg: ModelConfig, dtype):
    d, H, Kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": he_init(ks[0], (d, H * hd), d, dtype),
        "wk": he_init(ks[1], (d, Kv * hd), d, dtype),
        "wv": he_init(ks[2], (d, Kv * hd), d, dtype),
        "wo": he_init(ks[3], (H * hd, d), H * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Kv * hd,), dtype)
        p["bv"] = jnp.zeros((Kv * hd,), dtype)
    return p


def init_gqa_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    Kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, Kv, hd), dtype),
        "v": jnp.zeros((batch, cache_len, Kv, hd), dtype),
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def _write_cache(cache, k_new, v_new, positions):
    """Ring-buffer write: entries land at position % W. positions: (S,).
    When S > W only the LAST W entries are written (unique slots — a
    wrapped scatter with duplicate indices has undefined write order)."""
    W = cache["k"].shape[1]
    S = k_new.shape[1]
    if S > W:
        k_new, v_new, positions = k_new[:, -W:], v_new[:, -W:], positions[-W:]
    idx = positions % W
    cache = dict(cache)
    cdt = cache["k"].dtype  # supports quantized (fp8) caches
    cache["k"] = cache["k"].at[:, idx].set(k_new.astype(cdt))
    cache["v"] = cache["v"].at[:, idx].set(v_new.astype(cdt))
    cache["slot_pos"] = cache["slot_pos"].at[idx].set(positions)
    cache["pos"] = positions[-1] + 1
    return cache


def gqa_apply(params, cfg: ModelConfig, x, positions, cache, mode: AttnMode):
    """x: (B,S,d); positions: (S,) int32. Returns (out, new_cache)."""
    B, S, d = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Kv, hd)
    v = v.reshape(B, S, Kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    unroll = not cfg.scan_layers
    if mode.kind in ("train", "prefill"):
        # prefill attends over the FRESH K/V (window-masked), independent of
        # ring-buffer wrap-around; the cache write keeps only the last W.
        out = blocked_attention(
            q, k, v, positions, positions, window=mode.window,
            block_k=mode.block_k, unroll=unroll,
        )
        new_cache = (
            _write_cache(cache, k, v, positions) if mode.kind == "prefill" else cache
        )
    else:
        new_cache = _write_cache(cache, k, v, positions)
        out = blocked_attention(
            q,
            new_cache["k"],
            new_cache["v"],
            positions,
            new_cache["slot_pos"],
            window=mode.window,
            block_k=mode.block_k,
            unroll=unroll,
        )
    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bse,ed->bsd", out, params["wo"]), new_cache


# ===================================================================== MLA
def mla_init(rng, cfg: ModelConfig, dtype):
    d, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 6)
    return {
        "wq_a": he_init(ks[0], (d, qr), d, dtype),
        "q_norm": {"scale": jnp.ones((qr,), dtype)},
        "wq_b": he_init(ks[1], (qr, H * (nope + rope)), qr, dtype),
        "wkv_a": he_init(ks[2], (d, kvr + rope), d, dtype),
        "kv_norm": {"scale": jnp.ones((kvr,), dtype)},
        "wk_b": he_init(ks[3], (kvr, H * nope), kvr, dtype),
        "wv_b": he_init(ks[4], (kvr, H * dv), kvr, dtype),
        "wo": he_init(ks[5], (H * dv, d), H * dv, dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    """MLA caches the COMPRESSED latent (kv_lora + rope) — its memory win."""
    return {
        "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def _mla_qkv(params, cfg, x, positions):
    from repro.models.layers import rmsnorm

    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q_lat = rmsnorm(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["wq_a"]))
    q = jnp.einsum("bsr,re->bse", q_lat, params["wq_b"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    ckv, k_rope = kv_a[..., : cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank :]
    from repro.models.layers import rmsnorm as _rn

    ckv = _rn(params["kv_norm"], ckv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def mla_apply(params, cfg: ModelConfig, x, positions, cache, mode: AttnMode):
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    q_nope, q_rope, ckv, k_rope = _mla_qkv(params, cfg, x, positions)
    scale = 1.0 / ((nope + rope) ** 0.5)

    unroll = not cfg.scan_layers
    if mode.kind in ("train", "prefill"):
        # naive path: expand latent to per-head K/V (linear in S); prefill
        # attends over the FRESH latents and only writes the cache.
        if mode.kind == "prefill":
            cache = _write_mla_cache(cache, ckv, k_rope, positions)
        src_ckv, src_krope, kv_pos = ckv, k_rope, positions
        T = src_ckv.shape[1]
        k_nope = jnp.einsum("btr,re->bte", src_ckv, params["wk_b"]).reshape(
            B, T, H, nope
        )
        val = jnp.einsum("btr,re->bte", src_ckv, params["wv_b"]).reshape(B, T, H, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(src_krope[:, :, None, :], (B, T, H, rope))], -1
        )
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = blocked_attention(
            q, k, val, positions, kv_pos, window=mode.window,
            block_k=mode.block_k, scale=scale, unroll=unroll,
        )
    else:
        # absorbed decode: score/combine directly in latent space (MQA-like)
        cache = _write_mla_cache(cache, ckv, k_rope, positions)
        # q' = q_nope @ wk_b^T  (per head): (B,S,H,kvr)
        wk_b = params["wk_b"].reshape(kvr, H, nope)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk_b)
        q_full = jnp.concatenate([q_lat, q_rope], -1)  # (B,S,H,kvr+rope)
        k_full = jnp.concatenate([cache["ckv"], cache["krope"]], -1)  # (B,T,kvr+rope)
        out_lat = blocked_attention(
            q_full,
            k_full[:, :, None, :],
            cache["ckv"][:, :, None, :],
            positions,
            cache["slot_pos"],
            window=mode.window,
            block_k=mode.block_k,
            scale=scale,
            unroll=unroll,
        )  # (B,S,H,kvr)
        wv_b = params["wv_b"].reshape(kvr, H, dv)
        out = jnp.einsum("bshr,rhv->bshv", out_lat, wv_b)

    out = out.reshape(B, S, H * dv)
    return jnp.einsum("bse,ed->bsd", out, params["wo"]), cache


def _write_mla_cache(cache, ckv, k_rope, positions):
    W = cache["ckv"].shape[1]
    idx = positions % W
    cache = dict(cache)
    cdt = cache["ckv"].dtype  # supports quantized (fp8) caches
    cache["ckv"] = cache["ckv"].at[:, idx].set(ckv.astype(cdt))
    cache["krope"] = cache["krope"].at[:, idx].set(k_rope.astype(cdt))
    cache["slot_pos"] = cache["slot_pos"].at[idx].set(positions)
    cache["pos"] = positions[-1] + 1
    return cache
