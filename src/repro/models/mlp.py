"""SwiGLU MLP (llama-style gated feed-forward)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import he_init, silu


def mlp_init(rng, d: int, f: int, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "w1": he_init(ks[0], (d, f), d, dtype),  # gate
        "w3": he_init(ks[1], (d, f), d, dtype),  # up
        "w2": he_init(ks[2], (f, d), f, dtype),  # down
    }


def mlp_apply(params, x):
    h = silu(jnp.einsum("bsd,df->bsf", x, params["w1"])) * jnp.einsum(
        "bsd,df->bsf", x, params["w3"]
    )
    return jnp.einsum("bsf,fd->bsd", h, params["w2"])
