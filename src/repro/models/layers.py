"""Shared NN building blocks: norms, initializers, rotary embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def he_init(rng, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def embed_init(rng, shape, dtype=jnp.float32):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------- RMSNorm
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_nohead(x, eps: float = 1e-5):
    """Scale-free RMS norm (used for per-head RWKV group norm)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (head_dim//2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd//2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd//2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd//2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)
