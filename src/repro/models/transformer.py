"""Decoder-only transformer assembling all assigned architecture families.

Layers are stacked into homogeneous *groups* (deepseek-v3: leading dense
layers + MoE layers = two groups) and executed with `jax.lax.scan` over the
stacked parameters — small HLO, fast compiles at 95 layers, remat-friendly.

Modes:
  train    — full causal attention, no cache, returns loss-ready logits
  prefill  — causal attention, writes the KV cache, returns logits
  decode   — ONE new token against a seq_len cache (ring buffer when the
             sliding-window long-context variant is on)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import AttnMode
from repro.models.layers import embed_init, he_init, rmsnorm, rmsnorm_init
from repro.models.mlp import mlp_apply, mlp_init

IGNORE_LABEL = -1
MTP_WEIGHT = 0.3


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    name: str
    count: int
    kind: str  # dense | moe | rwkv | hybrid


def _layer_groups(cfg: ModelConfig):
    if cfg.attention_type == "rwkv":
        return [LayerGroup("rwkv", cfg.num_layers, "rwkv")]
    if cfg.attention_type == "hybrid":
        return [LayerGroup("hybrid", cfg.num_layers, "hybrid")]
    if cfg.moe:
        groups = []
        if cfg.first_dense_layers:
            groups.append(LayerGroup("dense", cfg.first_dense_layers, "dense"))
        groups.append(
            LayerGroup("moe", cfg.num_layers - cfg.first_dense_layers, "moe")
        )
        return groups
    return [LayerGroup("dense", cfg.num_layers, "dense")]


class Transformer:
    """Functional model: params are plain dict pytrees."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = _layer_groups(cfg)
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------ init
    def _attn_init(self, rng):
        if self.cfg.attention_type == "mla":
            return attn_lib.mla_init(rng, self.cfg, self.dtype)
        return attn_lib.gqa_init(rng, self.cfg, self.dtype)

    def _block_init(self, kind: str, rng):
        cfg, dt = self.cfg, self.dtype
        d = cfg.d_model
        ks = jax.random.split(rng, 6)
        if kind == "rwkv":
            return {
                "norm1": rmsnorm_init(d, dt),
                "time_mix": rwkv_lib.time_mix_init(ks[0], cfg, dt),
                "norm2": rmsnorm_init(d, dt),
                "channel_mix": rwkv_lib.channel_mix_init(ks[1], cfg, dt),
            }
        p = {
            "norm1": rmsnorm_init(d, dt),
            "attn": self._attn_init(ks[0]),
            "norm2": rmsnorm_init(d, dt),
        }
        if kind == "hybrid":
            p["ssm"] = ssm_lib.ssm_init(ks[1], cfg, dt)
            p["mix_attn"] = jnp.ones((d,), dt) * 0.5
            p["mix_ssm"] = jnp.ones((d,), dt) * 0.5
            p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, dt)
        elif kind == "moe":
            p["moe"] = moe_lib.moe_init(ks[1], cfg, dt)
            if cfg.dense_residual:
                p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, dt)
        else:
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, dt)
        return p

    def init(self, rng) -> Dict[str, Any]:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(rng, len(self.groups) + 4)
        params: Dict[str, Any] = {
            "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = he_init(
                ks[1], (cfg.d_model, cfg.vocab_size), cfg.d_model, dt
            )
        params["groups"] = {}
        for g, k in zip(self.groups, ks[2:]):
            layer_keys = jax.random.split(k, g.count)
            params["groups"][g.name] = jax.vmap(
                functools.partial(self._block_init, g.kind)
            )(layer_keys)
        if cfg.mtp:
            k_mtp = ks[len(self.groups) + 2]
            km = jax.random.split(k_mtp, 2)
            params["mtp"] = {
                "proj": he_init(km[0], (2 * cfg.d_model, cfg.d_model), 2 * cfg.d_model, dt),
                "block": self._block_init("dense", km[1]),
                "norm": rmsnorm_init(cfg.d_model, dt),
            }
        return params

    # ----------------------------------------------------------------- cache
    def _block_cache(self, kind: str, batch: int, cache_len: int, dtype):
        cfg = self.cfg
        if kind == "rwkv":
            return rwkv_lib.init_rwkv_state(cfg, batch, dtype)
        if cfg.attention_type == "mla":
            c = attn_lib.init_mla_cache(cfg, batch, cache_len, dtype)
        else:
            c = attn_lib.init_gqa_cache(cfg, batch, cache_len, dtype)
        if kind == "hybrid":
            c = {"attn": c, "ssm_state": ssm_lib.init_ssm_state(cfg, batch)}
        return c

    def init_cache(self, batch: int, cache_len: int, dtype=None):
        dtype = dtype or self.dtype
        out = {}
        for g in self.groups:
            single = self._block_cache(g.kind, batch, cache_len, dtype)
            out[g.name] = jax.tree.map(
                lambda a: jnp.tile(a[None], (g.count,) + (1,) * a.ndim), single
            )
        return out

    # ----------------------------------------------------------------- apply
    def _block_apply(self, kind: str, params, x, cache, positions, mode: AttnMode):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if kind == "rwkv":
            tm_state = (
                cache
                if cache
                else rwkv_lib.init_rwkv_state(cfg, x.shape[0], x.dtype)
            )
            h, tm_new = rwkv_lib.time_mix_apply(
                params["time_mix"], cfg, rmsnorm(params["norm1"], x, cfg.norm_eps),
                {"shift": tm_state["shift"], "wkv": tm_state["wkv"]},
            )
            x = x + h
            h, cm_new = rwkv_lib.channel_mix_apply(
                params["channel_mix"], rmsnorm(params["norm2"], x, cfg.norm_eps),
                tm_state["cm_shift"],
            )
            x = x + h
            new_cache = (
                {"shift": tm_new["shift"], "wkv": tm_new["wkv"], "cm_shift": cm_new}
                if cache
                else {}
            )
            return x, new_cache, aux

        attn_cache = cache.get("attn", cache) if cache else None
        xn = rmsnorm(params["norm1"], x, cfg.norm_eps)
        if cfg.attention_type == "mla":
            h, attn_cache_new = attn_lib.mla_apply(
                params["attn"], cfg, xn, positions, attn_cache, mode
            )
        else:
            h, attn_cache_new = attn_lib.gqa_apply(
                params["attn"], cfg, xn, positions, attn_cache, mode
            )
        if kind == "hybrid":
            ssm_state = (
                cache["ssm_state"]
                if cache
                else ssm_lib.init_ssm_state(cfg, x.shape[0])
            )
            h_ssm, ssm_new = ssm_lib.ssm_apply(params["ssm"], cfg, xn, ssm_state)
            h = params["mix_attn"] * h + params["mix_ssm"] * h_ssm
        x = x + h

        xn = rmsnorm(params["norm2"], x, cfg.norm_eps)
        if kind == "moe":
            h, aux = moe_lib.moe_apply(params["moe"], cfg, xn)
            if cfg.dense_residual:
                h = h + mlp_apply(params["mlp"], xn)
        else:
            h = mlp_apply(params["mlp"], xn)
        x = x + h

        if not cache:
            new_cache = {}
        elif kind == "hybrid":
            new_cache = {"attn": attn_cache_new, "ssm_state": ssm_new}
        else:
            new_cache = attn_cache_new
        return x, new_cache, aux

    def _run_group(self, group: LayerGroup, params, x, cache, positions, mode):
        if not self.cfg.scan_layers:
            # straight-line layers (dry-run cost pass: scan bodies are
            # counted once by XLA cost_analysis, so unroll for accounting)
            aux = jnp.zeros((), jnp.float32)
            new_caches = []
            for i in range(group.count):
                p_i = jax.tree.map(lambda a: a[i], params)
                c_i = jax.tree.map(lambda a: a[i], cache) if cache else {}
                x, c_new, a = self._block_apply(
                    group.kind, p_i, x, c_i, positions, mode
                )
                aux += a
                new_caches.append(c_new)
            if cache:
                new_cache = jax.tree.map(
                    lambda *xs: jnp.stack(xs, axis=0), *new_caches
                )
            else:
                new_cache = {}
            return x, new_cache, aux

        def body(carry, xs):
            x, aux = carry
            p, c = xs
            x, c_new, a = self._block_apply(group.kind, p, x, c, positions, mode)
            return (x, aux + a), c_new

        if self.cfg.remat and mode.kind == "train":
            body = jax.checkpoint(body)
        (x, aux), new_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params, cache if cache else {})
        )
        return x, new_cache, aux

    def forward(
        self,
        params,
        *,
        tokens: Optional[jax.Array] = None,
        embeds: Optional[jax.Array] = None,
        cache=None,
        positions: Optional[jax.Array] = None,
        mode: AttnMode = AttnMode("train"),
    ):
        """Returns (logits, new_cache, aux_dict). positions: (S,) int32."""
        cfg = self.cfg
        parts = []
        if embeds is not None:
            parts.append(embeds.astype(self.dtype))
        if tokens is not None:
            parts.append(jnp.take(params["embed"], tokens, axis=0))
        x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
        S = x.shape[1]
        if positions is None:
            positions = jnp.arange(S, dtype=jnp.int32)

        new_cache = {}
        aux_total = jnp.zeros((), jnp.float32)
        hidden_pre_final = None
        for g in self.groups:
            c = cache[g.name] if cache else None
            x, c_new, aux = self._run_group(
                g, params["groups"][g.name], x, c, positions, mode
            )
            new_cache[g.name] = c_new
            aux_total += aux
        hidden_pre_final = x
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        )
        logits = jnp.einsum("bsd,dv->bsv", x, head)
        return logits, (new_cache if cache else None), {
            "moe_aux": aux_total,
            "hidden": hidden_pre_final,
        }

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, mode: AttnMode = AttnMode("train")):
        """batch: {"tokens": (B,S+1)} | {"embeds": (B,S,d), "labels": (B,S)}
        | {"embeds": (B,P,d), "tokens": (B,St+1)} (vlm).
        Returns (loss, metrics)."""
        cfg = self.cfg
        embeds = batch.get("embeds")
        tokens = batch.get("tokens")
        if tokens is not None:
            inputs, tok_labels = tokens[:, :-1], tokens[:, 1:]
        else:
            inputs, tok_labels = None, batch["labels"]
        logits, _, aux = self.forward(
            params, tokens=inputs, embeds=embeds, mode=mode
        )
        if embeds is not None and tokens is not None:
            # vlm: no loss on the image-embedding prefix
            P = embeds.shape[1]
            prefix = jnp.full((tok_labels.shape[0], P), IGNORE_LABEL, tok_labels.dtype)
            labels = jnp.concatenate([prefix, tok_labels], axis=1)
        else:
            labels = tok_labels
        ce, acc = _masked_ce(logits, labels)
        total = ce + aux["moe_aux"]
        metrics = {"ce": ce, "moe_aux": aux["moe_aux"], "acc": acc}
        if cfg.mtp and tokens is not None:
            mtp_loss = self._mtp_loss(params, aux["hidden"], inputs, labels)
            total = total + MTP_WEIGHT * mtp_loss
            metrics["mtp"] = mtp_loss
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params, hidden, inputs, labels):
        """DeepSeek-V3 multi-token prediction: predict t+2 from [h_t; emb_{t+1}]."""
        cfg = self.cfg
        emb_next = jnp.take(params["embed"], inputs, axis=0)  # embeds of token t
        # shift: combine h_{t} with emb of token t+1 (= inputs shifted left)
        h = hidden[:, :-1]
        e = emb_next[:, 1:]
        z = jnp.concatenate([h, e], axis=-1)
        z = jnp.einsum("bsd,de->bse", z, params["mtp"]["proj"])
        S = z.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        z, _, _ = self._block_apply(
            "dense", params["mtp"]["block"], z, None, pos, AttnMode("train")
        )
        z = rmsnorm(params["mtp"]["norm"], z, cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", z, head)
        mtp_labels = labels[:, 1:]  # predict one further ahead
        ce, _ = _masked_ce(logits, mtp_labels)
        return ce

    # ------------------------------------------------------------- serving
    def prefill(self, params, *, tokens=None, embeds=None, cache_len: int,
                window: Optional[int] = None, cache_dtype=None):
        B = (tokens if tokens is not None else embeds).shape[0]
        cache = self.init_cache(B, cache_len, cache_dtype)
        mode = AttnMode("prefill", window=window)
        logits, cache, _ = self.forward(
            params, tokens=tokens, embeds=embeds, cache=cache, mode=mode
        )
        return logits[:, -1], cache

    def decode_step(self, params, cache, tokens, pos, window: Optional[int] = None):
        """tokens: (B,1) int32; pos: () int32 absolute position."""
        positions = pos[None].astype(jnp.int32)
        mode = AttnMode("decode", window=window)
        logits, cache, _ = self.forward(
            params, tokens=tokens, cache=cache, positions=positions, mode=mode
        )
        return logits[:, -1], cache


def _masked_ce(logits, labels):
    mask = labels != IGNORE_LABEL
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1)
    ce = -(ll * mask).sum() / denom
    acc = ((logits.argmax(-1) == safe) * mask).sum() / denom
    return ce, acc
