"""The paper's own test models (§V Examples V.1–V.3).

These are the exact objectives FedGiA is evaluated on in the paper, so the
numerical reproduction (benchmarks/table4.py etc.) uses them directly. Each
model exposes the same protocol as Transformer.loss: loss(params, batch) ->
(loss, metrics); params here is {"x": (n,)}.

Losses follow the paper's normalisation: per-client
  f_i(x) = (1/d_i) sum_j loss_j  (+ regulariser / d_i)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class LeastSquares:
    """Example V.1:  f_i(x) = 1/(2 d_i) ||A_i x - b_i||^2."""

    def __init__(self, n: int):
        self.n = n

    def init(self, rng):
        return {"x": jnp.zeros((self.n,), jnp.float32)}

    def loss(self, params, batch):
        A, b = batch["A"], batch["b"]
        mask = batch.get("mask")
        r = A @ params["x"] - b
        if mask is None:
            loss = 0.5 * jnp.mean(jnp.square(r))
        else:
            loss = 0.5 * jnp.sum(mask * jnp.square(r)) / jnp.maximum(mask.sum(), 1.0)
        return loss, {"loss": loss}

    def gram(self, batch):
        """H_i = B_i / d_i with B_i = A_i^T A_i (paper Table III, Ex. V.1)."""
        A, d = _masked(batch)
        return (A.T @ A) / d

    def lipschitz(self, batch):
        """r_i = ||B_i|| / d_i (spectral norm of the Hessian)."""
        H = self.gram(batch)
        return jnp.linalg.norm(H, ord=2)


def _masked(batch):
    """Apply the ragged-client mask: zero padded rows, return effective d_i."""
    A = batch["A"]
    mask = batch.get("mask")
    if mask is None:
        return A, A.shape[0]
    return A * mask[:, None], jnp.maximum(mask.sum(), 1.0)


class LogisticRegression:
    """Example V.2:  l2-regularised logistic loss,
    f_i(x) = (1/d_i) sum_j [ln(1+e^{<a,x>}) - b<a,x>] + mu/(2 d_i) ||x||^2."""

    def __init__(self, n: int, mu: float = 1e-3):
        self.n = n
        self.mu = mu

    def init(self, rng):
        return {"x": jnp.zeros((self.n,), jnp.float32)}

    def loss(self, params, batch):
        A, b = batch["A"], batch["b"]
        mask = batch.get("mask")
        z = A @ params["x"]
        per = jnp.logaddexp(0.0, z) - b * z
        if mask is None:
            d = A.shape[0]
            ll = jnp.sum(per) / d
        else:
            d = jnp.maximum(batch["mask"].sum(), 1.0)
            ll = jnp.sum(mask * per) / d
        reg = 0.5 * self.mu * jnp.sum(jnp.square(params["x"])) / d
        loss = ll + reg
        return loss, {"loss": loss}

    def gram(self, batch):
        """H_i = B_i/(4 d_i) (paper Table III, Ex. V.2): sigmoid' <= 1/4."""
        A, d = _masked(batch)
        return (A.T @ A) / (4.0 * d)

    def lipschitz(self, batch):
        _, d = _masked(batch)
        return jnp.linalg.norm(self.gram(batch), ord=2) + self.mu / d


class NonConvexLogistic:
    """Example V.3: logistic loss + non-convex regulariser
    mu/(2 d_i) sum_l x_l^2 / (1 + x_l^2)."""

    def __init__(self, n: int, mu: float = 1e-2):
        self.n = n
        self.mu = mu

    def init(self, rng):
        return {"x": jnp.zeros((self.n,), jnp.float32)}

    def loss(self, params, batch):
        A, b = batch["A"], batch["b"]
        mask = batch.get("mask")
        x = params["x"]
        z = A @ x
        per = jnp.logaddexp(0.0, z) - b * z
        if mask is None:
            d = A.shape[0]
            ll = jnp.sum(per) / d
        else:
            d = jnp.maximum(mask.sum(), 1.0)
            ll = jnp.sum(mask * per) / d
        x2 = jnp.square(x)
        reg = 0.5 * self.mu * jnp.sum(x2 / (1.0 + x2)) / d
        loss = ll + reg
        return loss, {"loss": loss}

    def gram(self, batch):
        """Paper Table III, Ex. V.3: B_i/(4 d_i) + mu I / d_i."""
        A, d = _masked(batch)
        return (A.T @ A) / (4.0 * d) + self.mu * jnp.eye(self.n) / d

    def lipschitz(self, batch):
        return jnp.linalg.norm(self.gram(batch), ord=2)
