from repro.models.transformer import Transformer
from repro.models.linear_models import LeastSquares, LogisticRegression, NonConvexLogistic
