"""Mixture-of-Experts layer: top-k routing, capacity-based gather/scatter
dispatch (TPU-classic "dropping" MoE, exact FLOPs accounting), optional
shared experts (deepseek-v3) and dense residual branch (arctic).

Dispatch uses gather (`jnp.take`) and scatter-add (`segment_sum`) rather
than one-hot einsums, so HLO FLOPs reflect real expert compute:
  E * C * (3 d f) per layer, with E*C ≈ capacity_factor * T * k.
Expert weights are sharded over the `model` mesh axis (expert parallelism);
GSPMD inserts the token all-to-all/all-reduce around the sharded expert
matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import he_init, silu
from repro.models.mlp import mlp_init, mlp_apply

CAPACITY_FACTOR = 1.25


def moe_init(rng, cfg: ModelConfig, dtype):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": he_init(ks[0], (d, E), d, jnp.float32),  # router in fp32
        "experts": {
            "w1": he_init(ks[1], (E, d, f), d, dtype),
            "w3": he_init(ks[2], (E, d, f), d, dtype),
            "w2": he_init(ks[3], (E, f, d), f, dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, f * cfg.num_shared_experts, dtype)
    return p


def expert_capacity(num_tokens: int, num_experts: int, k: int) -> int:
    cap = int(CAPACITY_FACTOR * num_tokens * k / num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def moe_apply(params, cfg: ModelConfig, x, *, router_dtype=jnp.float32):
    """x: (B,S,d). Returns (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(router_dtype), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style) ----
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32)
    ce = ce.at[expert_idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # ---- capacity-based dispatch (sort-based positions: O(Tk log Tk)
    # memory O(Tk), instead of the classic (Tk, E) one-hot cumsum) ----
    C = expert_capacity(T, E, k)
    flat_expert = expert_idx.reshape(-1)  # (T*k,)
    Tk = flat_expert.shape[0]
    order = jnp.argsort(flat_expert, stable=True)
    sorted_e = flat_expert[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
    pos_sorted = jnp.arange(Tk) - starts[sorted_e]
    pos = jnp.zeros((Tk,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C
    slot = flat_expert * C + jnp.where(keep, pos, 0)  # (T*k,) flat (E*C) slot
    token_of = jnp.repeat(jnp.arange(T), k)

    # scatter tokens into (E*C, d) expert buffers
    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[jnp.where(keep, slot, E * C)].set(
        jnp.take(xt, token_of, axis=0), mode="drop"
    )
    buf = buf.reshape(E, C, d)

    # expert FFN (E parallel matmuls; E sharded over `model` axis)
    w = params["experts"]
    h = silu(jnp.einsum("ecd,edf->ecf", buf, w["w1"])) * jnp.einsum(
        "ecd,edf->ecf", buf, w["w3"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, w["w2"]).reshape(E * C, d)

    # combine in SLOT space: scatter-add expert outputs to their tokens.
    # With out_buf sharded on E (expert parallelism) each shard scatters
    # only its own experts' slots and GSPMD finishes with ONE (T, d)
    # all-reduce — a token-indexed gather here would instead all-gather
    # the entire (E*C, d) buffer (measured 30x more collective traffic,
    # see EXPERIMENTS.md §Perf H3).
    tok_of_slot = jnp.full((E * C,), T, jnp.int32).at[
        jnp.where(keep, slot, E * C)
    ].set(token_of.astype(jnp.int32), mode="drop")
    gate_of_slot = jnp.zeros((E * C,), jnp.float32).at[
        jnp.where(keep, slot, E * C)
    ].set(gate_vals.reshape(-1), mode="drop")
    combined = jnp.zeros((T, d), jnp.float32).at[tok_of_slot].add(
        out_buf.astype(jnp.float32) * gate_of_slot[:, None], mode="drop"
    )
    out = combined.astype(x.dtype).reshape(B, S, d)

    if "shared" in params:
        out = out + mlp_apply(params["shared"], x)
    return out, aux * cfg.router_aux_coef


def moe_ref_dense(params, cfg: ModelConfig, x):
    """Oracle: every token through its top-k experts via dense per-expert
    masking (exact, no capacity drops). Test-only — O(E * T * d * f)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    w = params["experts"]
    out = jnp.zeros_like(xt)
    for e in range(E):
        h = silu(xt @ w["w1"][e]) * (xt @ w["w3"][e])
        y = h @ w["w2"][e]
        gate_e = ((expert_idx == e) * gate_vals).sum(-1)  # (T,)
        out = out + y * gate_e[:, None].astype(y.dtype)
    res = out.reshape(B, S, d)
    if "shared" in params:
        res = res + mlp_apply(params["shared"], x)
    return res
