"""Mixture-of-Experts layer: top-k routing, capacity-based gather/scatter
dispatch (TPU-classic "dropping" MoE, exact FLOPs accounting), optional
shared experts (deepseek-v3) and dense residual branch (arctic).

Capacity competition is scoped PER SEQUENCE POSITION: the group of B
tokens at position s competes for its own (E, C) slots, which is exactly
the group the serving path routes together at decode step s. That makes
the drop pattern causal — prefill+decode reproduce the train-mode
forward bit-for-bit at the routing level (tests/test_serve.py), where
a flattened (T*k,) group would let batch-0's late tokens steal capacity
from batch-1's early ones.

Dispatch uses gather (`jnp.take`) and scatter-add rather than one-hot
einsums, so HLO FLOPs reflect real expert compute:
  S * E * C * (3 d f) per layer, with C ≈ max(8, capacity_factor * B * k / E)
(the per-group capacity floor makes small-batch dispatch pay for at most
8 slots per expert per position). Expert weights are sharded over the
`model` mesh axis (expert parallelism); GSPMD inserts the token
all-to-all/all-reduce around the sharded expert matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import he_init, silu
from repro.models.mlp import mlp_init, mlp_apply

CAPACITY_FACTOR = 1.25


def moe_init(rng, cfg: ModelConfig, dtype):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": he_init(ks[0], (d, E), d, jnp.float32),  # router in fp32
        "experts": {
            "w1": he_init(ks[1], (E, d, f), d, dtype),
            "w3": he_init(ks[2], (E, d, f), d, dtype),
            "w2": he_init(ks[3], (E, f, d), f, dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, f * cfg.num_shared_experts, dtype)
    return p


def expert_capacity(num_tokens: int, num_experts: int, k: int) -> int:
    cap = int(CAPACITY_FACTOR * num_tokens * k / num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def moe_apply(params, cfg: ModelConfig, x, *, router_dtype=jnp.float32):
    """x: (B,S,d). Returns (out (B,S,d), aux_loss scalar).

    Routing (top-k, gates, aux loss) is per-token; capacity competition
    is per position group — the B tokens at sequence position s share one
    (E, C) slot budget, matching the decode path's step-s routing group.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(router_dtype), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style, whole batch) ----
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32)
    ce = ce.at[expert_idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # ---- capacity-based dispatch, one causal group per position
    # (sort-based positions: O(Bk log Bk) per group, memory O(Bk),
    # instead of the classic (Bk, E) one-hot cumsum) ----
    C = expert_capacity(B, E, k)
    xg = x.transpose(1, 0, 2)  # (S,B,d) — group s = batch column at pos s
    eg = expert_idx.reshape(B, S, k).transpose(1, 0, 2)  # (S,B,k)
    gg = gate_vals.reshape(B, S, k).transpose(1, 0, 2)

    def dispatch(xs, es, gs):
        """One capacity group: xs (B,d), es/gs (B,k)."""
        flat_e = es.reshape(-1)  # (B*k,)
        Bk = flat_e.shape[0]
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
        pos_sorted = jnp.arange(Bk) - starts[sorted_e]
        pos = jnp.zeros((Bk,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32)
        )
        keep = pos < C
        slot = jnp.where(keep, flat_e * C + pos, E * C)  # E*C = drop sentinel
        token_of = jnp.repeat(jnp.arange(B), k)
        buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(
            jnp.take(xs, token_of, axis=0), mode="drop"
        )
        tok_of_slot = jnp.full((E * C,), B, jnp.int32).at[slot].set(
            token_of.astype(jnp.int32), mode="drop"
        )
        gate_of_slot = jnp.zeros((E * C,), jnp.float32).at[slot].set(
            gs.reshape(-1), mode="drop"
        )
        return buf.reshape(E, C, d), tok_of_slot, gate_of_slot

    buf, tok_of_slot, gate_of_slot = jax.vmap(dispatch)(xg, eg, gg)

    # expert FFN (E parallel matmuls per group; E sharded over `model` axis)
    w = params["experts"]
    h = silu(jnp.einsum("secd,edf->secf", buf, w["w1"])) * jnp.einsum(
        "secd,edf->secf", buf, w["w3"]
    )
    out_buf = jnp.einsum("secf,efd->secd", h, w["w2"]).reshape(S, E * C, d)

    # combine in SLOT space: scatter-add expert outputs to their tokens.
    # With out_buf sharded on E (expert parallelism) each shard scatters
    # only its own experts' slots and GSPMD finishes with ONE (S, B, d)
    # all-reduce — a token-indexed gather here would instead all-gather
    # the entire (S, E*C, d) buffer (measured 30x more collective traffic,
    # see EXPERIMENTS.md §Perf H3).
    def combine(ob, tos, gos):
        return jnp.zeros((B, d), jnp.float32).at[tos].add(
            ob.astype(jnp.float32) * gos[:, None], mode="drop"
        )

    combined = jax.vmap(combine)(out_buf, tok_of_slot, gate_of_slot)  # (S,B,d)
    out = combined.transpose(1, 0, 2).astype(x.dtype)

    if "shared" in params:
        out = out + mlp_apply(params["shared"], x)
    return out, aux * cfg.router_aux_coef


def moe_ref_dense(params, cfg: ModelConfig, x):
    """Oracle: every token through its top-k experts via dense per-expert
    masking (exact, no capacity drops). Test-only — O(E * T * d * f)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    w = params["experts"]
    out = jnp.zeros_like(xt)
    for e in range(E):
        h = silu(xt @ w["w1"][e]) * (xt @ w["w3"][e])
        y = h @ w["w2"][e]
        gate_e = ((expert_idx == e) * gate_vals).sum(-1)  # (T,)
        out = out + y * gate_e[:, None].astype(y.dtype)
    res = out.reshape(B, S, d)
    if "shared" in params:
        res = res + mlp_apply(params["shared"], x)
    return res
