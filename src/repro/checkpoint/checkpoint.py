"""Pytree checkpointing: npz for leaves + json for the treedef/metadata.

Round-robust: checkpoints are written atomically (tmp + rename) and named
by step; `load_checkpoint` restores the exact pytree structure and dtypes,
including federated algorithm state (client duals etc.).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_META = "meta.json"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves, dtypes = [], [], []
    for path, leaf in flat:
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if arr.dtype.name == "bfloat16":  # npz cannot store bf16
            arr = arr.astype(np.float32)
        names.append(jax.tree_util.keystr(path))
        leaves.append(arr)
    return names, leaves, dtypes, treedef


def save_checkpoint(directory: str, step: int, tree, extra: Optional[Dict] = None):
    os.makedirs(directory, exist_ok=True)
    names, leaves, dtypes, _ = _flatten_with_names(tree)
    tmp = tempfile.mkdtemp(dir=directory)
    arrays = {f"leaf_{i}": l for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "names": names, "dtypes": dtypes, "extra": extra or {}}
    with open(os.path.join(tmp, _META), "w") as f:
        json.dump(meta, f)
    final = os.path.join(directory, f"ckpt_{step:08d}")
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.fullmatch(r"ckpt_(\d+)", f))
    ]
    return max(steps) if steps else None


def load_extra(directory: str, step: int) -> Dict:
    """The checkpoint's `extra` metadata alone (json, no npz read) — lets
    callers vet e.g. a config fingerprint BEFORE deserializing a carry
    whose structure may not even match theirs."""
    path = os.path.join(directory, f"ckpt_{step:08d}")
    with open(os.path.join(path, _META)) as f:
        return json.load(f)["extra"]


def load_checkpoint(directory: str, step: int, tree_like) -> Tuple[Any, Dict]:
    """tree_like: a pytree with the target structure (values ignored)."""
    path = os.path.join(directory, f"ckpt_{step:08d}")
    with open(os.path.join(path, _META)) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(meta["names"]))]
    ref_leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(ref_leaves) == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, target structure has "
        f"{len(ref_leaves)}"
    )
    import jax.numpy as jnp

    restored = [
        jnp.asarray(l, dtype=r.dtype) if hasattr(r, "dtype") else jnp.asarray(l)
        for l, r in zip(leaves, ref_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, restored), meta["extra"]
