"""PartitionSpec factories for every pytree the launchers shard.

Conventions (Megatron-style tensor parallelism over the `model` axis):
  * projections INTO heads/ff/experts shard their OUTPUT dim over `model`;
    projections back to d_model shard their INPUT dim over `model`;
  * MoE expert stacks shard the EXPERT dim over `model` (expert parallelism);
  * embedding / lm_head shard the vocab-adjacent dim over `model`;
  * federated client states carry a leading client axis sharded over
    `FedConfig.client_axes`; remaining dims follow the parameter rule;
  * activations/batches shard batch over the data-ish axes.

Specs are derived from leaf PATH NAMES via tree_map_with_path, so they stay
correct for every architecture family without per-arch spec tables.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.config import FedConfig, ModelConfig

# rules: leaf name -> (spec WITHOUT the scan-stack L dim)
# "out_model": shard last dim over model; "in_model": shard first dim;
# None: replicate.
_RULES = {
    # gqa attention
    "wq": "out_model", "wk": "out_model", "wv": "out_model", "wo": "in_model",
    "bq": "vec_model", "bk": "vec_model", "bv": "vec_model",
    # mla
    "wq_a": None, "wq_b": "out_model", "wkv_a": None,
    "wk_b": "out_model", "wv_b": "out_model",
    # mlp
    "w1": "out_model", "w3": "out_model", "w2": "in_model",
    # moe (leading expert dim)
    "router": None,
    # rwkv
    "wr": "out_model", "wg": "out_model",
    "decay_w1": None, "decay_w2": None, "decay_bias": None,
    "mu": None, "mu_k": None, "mu_r": None, "bonus_u": "head_model",
    # ssm
    "in_x": "out_model", "in_z": "out_model", "w_dt": "out_model",
    "dt_bias": "vec_model", "w_B": None, "w_C": None,
    "A_log": "in_model", "D": "vec_model",
    "mix_attn": None, "mix_ssm": None,
    # norms / misc
    "scale": None, "proj": None,
}


def _leaf_rule(path) -> Optional[str]:
    keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    name = keys[-1]
    if name == "embed":
        return "emb"
    if name == "lm_head":
        return "out_model"
    if "experts" in keys:
        return "expert"
    return _RULES.get(name)


def _spec_for(rule: Optional[str], ndim: int, model_axis: str) -> P:
    if rule is None:
        return P()
    if rule == "emb":
        return P(None, model_axis) if ndim == 2 else P()
    if rule == "out_model":
        return P(*([None] * (ndim - 1) + [model_axis]))
    if rule == "in_model":
        return P(*([model_axis] + [None] * (ndim - 1)))
    if rule == "vec_model":
        return P(*([None] * (ndim - 1) + [model_axis]))
    if rule == "head_model":
        return P(*([model_axis] + [None] * (ndim - 1)))
    if rule == "expert":
        return P(*([model_axis] + [None] * (ndim - 1)))
    raise ValueError(rule)


def param_specs(cfg: ModelConfig, params_shape, model_axis: str = "model"):
    """Specs matching Transformer.init output (scan-stacked group leaves).

    params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""

    def assign(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        stacked = "groups" in keys or ("block" in keys)
        rule = _leaf_rule(path)
        ndim = len(leaf.shape)
        if "groups" in keys:  # leading L scan dim
            inner = _spec_for(rule, ndim - 1, model_axis)
            return P(None, *inner)
        return _spec_for(rule, ndim, model_axis)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def fed_state_specs(fed: FedConfig, cfg: Optional[ModelConfig], state_shape,
                    model_axis: str = "model"):
    """Specs for a federated algorithm state: client-stacked leaves get the
    client axes on dim 0; server params follow param rules; scalars replicate.

    fed.fsdp_axes: client-state inner dims additionally sharded over these
    axes (first unassigned dim gets them) — FedGiA's per-client (z, pi)
    copies are the memory floor for giant archs, FSDP is how they fit.
    fed.replicate_params: drop the model-axis assignment entirely (pure DP
    within the client; gradient all-reduce once per round)."""
    client = fed.client_axes if len(fed.client_axes) > 1 else fed.client_axes[0]

    def assign(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        top = keys[0]
        ndim = len(leaf.shape)
        if top in ("sigma", "r", "round", "step", "rng"):
            return P()
        if top in ("gram_chol",):
            return P(client, *([None] * (ndim - 1)))
        param_path = path[1:]
        rule = _leaf_rule(param_path) if len(param_path) else None
        if fed.replicate_params:
            # replicate the trunk, but KEEP the lm_head vocab-sharded:
            # unsharded logits (B*S x vocab per client) dominate HBM
            # otherwise (measured +14 GiB/chip on tinyllama train, §Perf H1).
            # The embed table IS replicated — a vocab-sharded gather lowers
            # to a one-hot matmul (measured 5x FLOPs blow-up, §Perf H1b).
            name = (
                getattr(param_path[-1], "key", getattr(param_path[-1], "name", ""))
                if param_path else ""
            )
            if name != "lm_head":
                rule = None
        stacked_client = top in ("z", "pi", "h", "lam", "ci", "xc")
        scan_stacked = "groups" in keys
        core_ndim = ndim - (1 if stacked_client else 0) - (1 if scan_stacked else 0)
        inner = _spec_for(rule, core_ndim, model_axis)
        dims = list(inner)
        if stacked_client and fed.fsdp_axes and core_ndim >= 1:
            # shard the first unassigned inner dim over whichever fsdp axes
            # this leaf does not already use
            used = set()
            for e in dims:
                for a in (e if isinstance(e, tuple) else (e,)):
                    if a:
                        used.add(a)
            free = tuple(a for a in fed.fsdp_axes if a not in used)
            if free:
                for i, e in enumerate(dims):
                    if e is None:
                        dims[i] = free if len(free) > 1 else free[0]
                        break
        if scan_stacked:
            dims = [None] + dims
        if stacked_client:
            dims = [client] + dims
        return P(*dims)

    return jax.tree_util.tree_map_with_path(assign, state_shape)


def train_batch_specs(fed: FedConfig, batch_shape, mesh_axes: Tuple[str, ...]):
    """Stacked client batches: client axis over client_axes, per-client batch
    dim over any remaining data-ish axes."""
    client = fed.client_axes if len(fed.client_axes) > 1 else fed.client_axes[0]
    leftover = [
        a for a in mesh_axes
        if a not in fed.client_axes and (a != "model" or fed.replicate_params)
    ]
    bdim = tuple(leftover) if len(leftover) > 1 else (leftover[0] if leftover else None)

    def assign(path, leaf):
        ndim = len(leaf.shape)
        dims = [client] + [None] * (ndim - 1)
        if ndim >= 2 and bdim is not None:
            dims[1] = bdim
        return P(*dims)

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def serve_token_specs(batch: int, data_axes: Tuple[str, ...], shape_ndim: int = 2):
    """Token batches for serving: batch over data axes (replicated if B=1)."""
    import math

    total = None
    if batch > 1:
        total = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    return P(total, *([None] * (shape_ndim - 1)))


def cache_specs(cfg: ModelConfig, cache_shape, batch: int,
                data_axes: Tuple[str, ...], model_axis: str = "model",
                model_size: int = 16):
    """KV/recurrent caches: (L, B, ...) leaves — batch over data axes (if
    B > 1), head-ish dims over model (falling back to the head_dim axis when
    the head count does not divide the model-axis size)."""
    baxis = None
    if batch > 1:
        baxis = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]

    def head_or_dim(nheads: int, hdim: int):
        """(head_spec, dim_spec) — shard whichever divides the model axis."""
        if nheads % model_size == 0:
            return model_axis, None
        if hdim % model_size == 0:
            return None, model_axis
        return None, None

    def assign(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = keys[-1]
        ndim = len(leaf.shape)
        if name in ("pos", "slot_pos"):
            return P(*([None] * ndim))
        if name in ("k", "v"):  # (L,B,W,Kv,hd)
            hs, ds = head_or_dim(cfg.num_kv_heads, cfg.head_dim)
            return P(None, baxis, None, hs, ds)
        if name in ("ckv", "krope"):  # (L,B,W,r) — latent shared across heads
            return P(None, baxis, None, None)
        if name == "wkv":  # (L,B,H,hdk,hdv)
            hs, ds = head_or_dim(cfg.num_heads, cfg.rwkv_head_size)
            return P(None, baxis, hs, ds, None)
        if name in ("shift", "cm_shift"):  # (L,B,d)
            return P(None, baxis, "model" if cfg.d_model % model_size == 0 else None)
        if name == "ssm_state":  # (L,B,di,st)
            return P(
                None, baxis,
                model_axis if cfg.d_model % model_size == 0 else None, None,
            )
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def sanitize_specs(specs, shapes, mesh):
    """Drop any spec axis whose mesh extent does not divide the array dim —
    GSPMD requires exact divisibility for explicit in/out shardings."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, sds):
        dims = list(spec) + [None] * (len(sds.shape) - len(spec))
        out = []
        for i, e in enumerate(dims):
            if e is None:
                out.append(None)
                continue
            axes = e if isinstance(e, tuple) else (e,)
            prod = 1
            for a in axes:
                prod *= sizes[a]
            out.append(e if sds.shape[i] % prod == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, shapes)
