from repro.sharding.specs import (
    param_specs,
    fed_state_specs,
    train_batch_specs,
    cache_specs,
    serve_token_specs,
    sanitize_specs,
)
