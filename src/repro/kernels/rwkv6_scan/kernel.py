"""RWKV-6 recurrence, chunked over time with the state resident in VMEM.

  y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
  S_t = diag(w_t) S_{t-1} + k_t v_t^T

The XLA lax.scan lowering round-trips the (hd x hd) state through HBM every
timestep; here the state stays in a VMEM scratch for the whole sequence
while (r,k,v,w) stream through in (1, 1, block_t, hd) tiles — grid
(B, H, T/block_t) with the time dimension sequential. Per-step work is a
rank-1 update + matvec on (hd, hd) = (64, 64): VPU/MXU friendly.

TPU adaptation of the CUDA chunked-WKV kernel from the RWKV repo: the
shared-memory per-warp state becomes a VMEM scratch per (batch, head) grid
cell; warp-level parallelism over heads becomes grid parallelism.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 64

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases;
# accept either so the kernel imports on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sfin_ref, s_scr, *,
            block_t: int, seq_len: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)  # (hd,)

    def step(t, S):
        rt = r_ref[0, 0, t].astype(jnp.float32)  # (hd,)
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]  # (hdk, hdv)
        # y_t = (r·(u*k)) v + r @ S
        y = jnp.sum(rt * u * kt) * vt + jax.lax.dot_general(
            rt[None, :], S, (((1,), (0,)), ((), ()))
        ).reshape(-1)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        return wt[:, None] * S + kv

    S = jax.lax.fori_loop(0, block_t, step, s_scr[...])
    s_scr[...] = S

    @pl.when(ti == nt - 1)
    def _fin():
        sfin_ref[0, 0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan_kernel(r, k, v, w, u, *, block_t: int = DEFAULT_BLOCK_T,
                      interpret: bool = False):
    """r,k,v,w: (B,H,T,hd); u: (H,hd). Returns (y (B,H,T,hd), S (B,H,hd,hd))."""
    B, H, T, hd = r.shape
    block_t = min(block_t, T)
    pad = (-T) % block_t
    if pad:
        padt = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r, k, v = padt(r), padt(k), padt(v)
        # pad decay with ones so the state is unchanged on padded steps
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)), constant_values=1.0)
    Tp = T + pad

    grid = (B, H, Tp // block_t)
    seq_spec = pl.BlockSpec((1, 1, block_t, hd), lambda b, h, t: (b, h, t, 0))
    u_spec = pl.BlockSpec((1, hd), lambda b, h, t: (h, 0))
    y_spec = seq_spec
    s_spec = pl.BlockSpec((1, 1, hd, hd), lambda b, h, t: (b, h, 0, 0))

    y, s_fin = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t, seq_len=T),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec],
        out_specs=[y_spec, s_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tp, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(r, k, v, w, u)
    return y[:, :, :T], s_fin
