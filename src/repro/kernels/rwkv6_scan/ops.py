"""Public wrapper for the chunked RWKV-6 recurrence kernel."""
from __future__ import annotations

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_kernel
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def rwkv6_scan(r, k, v, w, u, *, use_kernel: bool = True,
               interpret: bool = False, block_t: int = 64):
    """r,k,v,w: (B,H,T,hd); u: (H,hd) -> (y (B,H,T,hd), S (B,H,hd,hd))."""
    if not use_kernel:
        return rwkv6_scan_ref(r, k, v, w, u)
    return rwkv6_scan_kernel(r, k, v, w, u, block_t=block_t, interpret=interpret)
