"""Oracle: the models/rwkv.py lax.scan recurrence, reshaped to kernel layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.rwkv import wkv6_scan


def rwkv6_scan_ref(r, k, v, w, u):
    """r,k,v,w: (B,H,T,hd); u: (H,hd). Returns (y, final_state)."""
    tr = lambda a: a.swapaxes(1, 2)  # -> (B,T,H,hd)
    B, H, T, hd = r.shape
    state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y, s = wkv6_scan(
        tr(r).astype(jnp.float32),
        tr(k).astype(jnp.float32),
        tr(v).astype(jnp.float32),
        tr(w).astype(jnp.float32),
        u.astype(jnp.float32),
        state0,
    )
    return tr(y).astype(r.dtype), s
