"""Public wrapper: pads the parameter stream to the lane width, dispatches
to the Pallas kernel (TPU) or the jnp reference (CPU / interpret)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fedgia_update.kernel import LANES, fedgia_update_kernel
from repro.kernels.fedgia_update.ref import fedgia_update_ref


def fedgia_update(xbar, gbar, pi, h, sel, sigma, m, *, k0: int,
                  use_kernel: bool = True, interpret: bool = False):
    """Flattened-vector FedGiA round update. All arrays (N,)."""
    if not use_kernel:
        return fedgia_update_ref(xbar, gbar, pi, h, sel, sigma, m, k0=k0)
    n = xbar.shape[0]
    pad = (-n) % LANES
    if pad:
        pad1 = lambda v: jnp.pad(v, (0, pad))
        xbar, gbar, pi, h = map(pad1, (xbar, gbar, pi, h))
    x, p, z = fedgia_update_kernel(
        xbar, gbar, pi, h,
        jnp.asarray(sel), jnp.asarray(sigma, jnp.float32), m,
        k0=k0, interpret=interpret,
    )
    if pad:
        x, p, z = x[:n], p[:n], z[:n]
    return x, p, z
