"""Public wrapper: pads the parameter stream to the lane width, dispatches
to the Pallas kernel (TPU) or the jnp reference (CPU / interpret)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fedgia_update.kernel import (
    LANES,
    fedgia_update_batched_kernel,
    fedgia_update_batched_kernel_donated,
    fedgia_update_kernel,
)
from repro.kernels.fedgia_update.ref import fedgia_update_ref


def kernel_by_default() -> bool:
    """The `use_kernel=None` auto-selection: Pallas on TPU, the fused jnp
    paths elsewhere (CPU tests opt in explicitly with interpret=True)."""
    return jax.default_backend() == "tpu"


def fedgia_update(xbar, gbar, pi, h, sel, sigma, m, *, k0: int,
                  use_kernel: bool = True, interpret: bool = False):
    """Flattened-vector FedGiA round update. All arrays (N,)."""
    if not use_kernel:
        return fedgia_update_ref(xbar, gbar, pi, h, sel, sigma, m, k0=k0)
    n = xbar.shape[0]
    pad = (-n) % LANES
    if pad:
        pad1 = lambda v: jnp.pad(v, (0, pad))
        xbar, gbar, pi, h = map(pad1, (xbar, gbar, pi, h))
    x, p, z = fedgia_update_kernel(
        xbar, gbar, pi, h,
        jnp.asarray(sel), jnp.asarray(sigma, jnp.float32), m,
        k0=k0, interpret=interpret,
    )
    if pad:
        x, p, z = x[:n], p[:n], z[:n]
    return x, p, z


def fedgia_update_flat(xbar_c, gbar, pi, h, sel, sigma, m, *, k0: int,
                       use_kernel: bool = True, interpret: bool = False,
                       donate: bool = False):
    """Batched flat-buffer round update: the whole (mb, N) client-state
    buffer in one pass (the flat engine's ADMM/GD branch, vmapped over the
    client axis in a single pallas grid).

    `xbar_c` is the per-client anchor view — a broadcast of x̄ in
    synchronous rounds, the stale per-client buffer in async rounds —
    and `sel` the (mb,) ADMM/GD branch select. `use_kernel=False` runs
    the jnp oracle (`ref.py`) broadcast over the client axis, which the
    tier-1 kernel tests pin against the interpret-mode kernel.

    `donate=True` consumes the xbar_c / gbar / pi buffers: the kernel
    aliases each onto the matching output (x' <- xbar, pi' <- pi,
    z' <- gbar), so the update runs in place with no extra (mb, N)
    temporary — the caller must treat those arrays as dead afterwards.
    Fp-identical to the undonated path (aliasing changes buffers, not
    math); requires lane-aligned N (the engine's RavelSpec pads to 128),
    since a ragged tail would force a padded copy and defeat the alias.
    """
    if not use_kernel:
        return fedgia_update_ref(xbar_c, gbar, pi, h, sel[:, None], sigma, m,
                                 k0=k0)
    mb, n = xbar_c.shape
    pad = (-n) % LANES
    if pad:
        pad1 = lambda v: jnp.pad(v, ((0, 0), (0, pad)))
        xbar_c, gbar, pi, h = map(pad1, (xbar_c, gbar, pi, h))
    call = (fedgia_update_batched_kernel_donated if donate and not pad
            else fedgia_update_batched_kernel)
    x, p, z = call(
        xbar_c, gbar, pi, h,
        jnp.asarray(sel), jnp.asarray(sigma, jnp.float32), m,
        k0=k0, interpret=interpret,
    )
    if pad:
        x, p, z = x[:, :n], p[:, :n], z[:, :n]
    return x, p, z
