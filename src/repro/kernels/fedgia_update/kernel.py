"""Fused FedGiA client update (paper eqs (12)-(14) / (15)-(17)).

One elementwise pass over the flattened parameter vector computes the
whole k0-step round in the collapsed closed form (DESIGN §6 B1):

  D    = 1 / (h/m + sigma)           (diagonal H)
  a    = 1 - sigma * D
  base = pi + g
  ADMM branch:  pi' = a^k0 base - g ;  x' = xbar - D a^(k0-1) base
  GD   branch:  pi' = -g           ;  x' = xbar
  both:         z'  = x' + pi'/sigma

The unfused implementation would make ~9 HBM round-trips over model-size
buffers (three updates, k0 times for the scan variant); this kernel makes
4 reads + 3 writes. Memory-bound => the roofline win is the traffic ratio.

Block layout: the 1-D parameter stream is viewed as (rows, 128) lanes and
tiled (BLOCK_ROWS, 128) per grid step — MXU-free, pure VPU elementwise,
lane dimension 128 matches the TPU vector registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BLOCK_ROWS = 512  # (512, 128) fp32 = 256 KiB per operand block in VMEM


def _kernel(sel_ref, scal_ref, xbar_ref, g_ref, pi_ref, h_ref,
            x_out_ref, pi_out_ref, z_out_ref, *, k0: int):
    sigma = scal_ref[0]
    inv_m = scal_ref[1]
    xbar = xbar_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    pi = pi_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)

    d = 1.0 / (h * inv_m + sigma)
    a = 1.0 - sigma * d
    base = pi + g
    ak1 = a ** (k0 - 1)
    pi_admm = ak1 * a * base - g
    x_admm = xbar - d * ak1 * base

    is_sel = sel_ref[0] > 0
    x_new = jnp.where(is_sel, x_admm, xbar)
    pi_new = jnp.where(is_sel, pi_admm, -g)
    z_new = x_new + pi_new / sigma

    x_out_ref[...] = x_new.astype(x_out_ref.dtype)
    pi_out_ref[...] = pi_new.astype(pi_out_ref.dtype)
    z_out_ref[...] = z_new.astype(z_out_ref.dtype)


def _batched_kernel(sel_ref, scal_ref, xbar_ref, g_ref, pi_ref, h_ref,
                    x_out_ref, pi_out_ref, z_out_ref, *, k0: int):
    """One (client, row-block) grid step of the batched round update.

    Identical math to `_kernel`, but the client index is grid dimension 0
    and the per-client ADMM/GD branch select comes from the (m,) SMEM
    `sel_ref` — the whole round's client axis runs in ONE pallas_call
    instead of m dispatches."""
    i = pl.program_id(0)
    sigma = scal_ref[0]
    inv_m = scal_ref[1]
    xbar = xbar_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    pi = pi_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)

    d = 1.0 / (h * inv_m + sigma)
    a = 1.0 - sigma * d
    base = pi + g
    ak1 = a ** (k0 - 1)
    pi_admm = ak1 * a * base - g
    x_admm = xbar - d * ak1 * base

    is_sel = sel_ref[i] > 0
    x_new = jnp.where(is_sel, x_admm, xbar)
    pi_new = jnp.where(is_sel, pi_admm, -g)
    z_new = x_new + pi_new / sigma

    x_out_ref[...] = x_new.astype(x_out_ref.dtype)
    pi_out_ref[...] = pi_new.astype(pi_out_ref.dtype)
    z_out_ref[...] = z_new.astype(z_out_ref.dtype)


# Flattened pallas_call inputs are (sel, scal, xbar, gbar, pi, h) =
# indices 0..5 and outputs (x', pi', z') = 0..2. The donated path aliases
# the three model-size input streams onto the shape/dtype-matched outputs
# so the collapsed update writes the (m, N) state in place:
#   x'  <- xbar   (the anchor buffer becomes the new client params)
#   pi' <- pi     (the multiplier updates in place)
#   z'  <- gbar   (the 1/m-scaled gradient buffer becomes the new z)
_DONATE_ALIASES = {2: 0, 4: 1, 3: 2}


def _batched_call(xbar, gbar, pi, h, sel, sigma, m, *, k0: int,
                  interpret: bool, donate: bool):
    mb, n = xbar.shape
    rows = n // LANES
    br = min(BLOCK_ROWS, rows)
    grid = (mb, pl.cdiv(rows, br))

    def reshape(v):
        return v.reshape(mb, rows, LANES)

    scal = jnp.stack([sigma.astype(jnp.float32), jnp.float32(1.0 / m)])
    sel_arr = sel.astype(jnp.int32)

    block = pl.BlockSpec((1, br, LANES), lambda i, j: (i, j, 0))
    rep = pl.BlockSpec(memory_space=pltpu.SMEM)
    out_shape = [jax.ShapeDtypeStruct((mb, rows, LANES), xbar.dtype)] * 3
    x_new, pi_new, z_new = pl.pallas_call(
        functools.partial(_batched_kernel, k0=k0),
        grid=grid,
        in_specs=[rep, rep, block, block, block, block],
        out_specs=[block, block, block],
        out_shape=out_shape,
        input_output_aliases=_DONATE_ALIASES if donate else {},
        interpret=interpret,
    )(sel_arr, scal, reshape(xbar), reshape(gbar), reshape(pi), reshape(h))
    return (x_new.reshape(mb, n), pi_new.reshape(mb, n),
            z_new.reshape(mb, n))


@functools.partial(jax.jit, static_argnames=("k0", "interpret"))
def fedgia_update_batched_kernel(xbar, gbar, pi, h, sel, sigma, m, *,
                                 k0: int, interpret: bool = False):
    """Batched flat round update: all inputs (mb, N) with N % 128 == 0
    (ops.py pads); sel: (mb,) bool — client i's ADMM/GD branch select;
    sigma: () f32; m: GLOBAL client count (the 1/m gradient scale).
    Returns (x', pi', z'), each (mb, N).

    Grid is (clients, row blocks): one kernel launch covers the whole
    (m, N) client-state buffer — the flat engine's round is a single
    fused elementwise pass instead of per-leaf (or per-client) dispatch.
    """
    return _batched_call(xbar, gbar, pi, h, sel, sigma, m,
                         k0=k0, interpret=interpret, donate=False)


@functools.partial(jax.jit, static_argnames=("k0", "interpret"),
                   donate_argnums=(0, 1, 2))
def fedgia_update_batched_kernel_donated(xbar, gbar, pi, h, sel, sigma, m, *,
                                         k0: int, interpret: bool = False):
    """Donated twin of `fedgia_update_batched_kernel`: the (mb, N) xbar /
    gbar / pi buffers are consumed — `donate_argnums` releases them to XLA
    and `input_output_aliases` maps each onto the matching output (see
    `_DONATE_ALIASES`), so the round update allocates ZERO extra
    model-size temporaries (`memory_analysis()` shows the aliased bytes,
    tests/test_kernels.py). The caller must not reuse the donated arrays
    afterwards (doing so raises — the buffer is genuinely gone); `h` and
    the scalars stay borrowed.
    """
    return _batched_call(xbar, gbar, pi, h, sel, sigma, m,
                         k0=k0, interpret=interpret, donate=True)


@functools.partial(jax.jit, static_argnames=("k0", "interpret"))
def fedgia_update_kernel(xbar, gbar, pi, h, sel, sigma, m, *, k0: int,
                         interpret: bool = False):
    """All inputs (N,) with N % 128 == 0 (ops.py pads); sel: () bool;
    sigma: () f32; m: client count. Returns (x', pi', z')."""
    n = xbar.shape[0]
    rows = n // LANES
    br = min(BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, br),)

    def reshape(v):
        return v.reshape(rows, LANES)

    scal = jnp.stack([sigma.astype(jnp.float32), jnp.float32(1.0 / m)])
    sel_arr = sel.astype(jnp.int32).reshape(1)

    block = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    rep = pl.BlockSpec(memory_space=pltpu.SMEM)
    out_shape = [jax.ShapeDtypeStruct((rows, LANES), xbar.dtype)] * 3
    x_new, pi_new, z_new = pl.pallas_call(
        functools.partial(_kernel, k0=k0),
        grid=grid,
        in_specs=[rep, rep, block, block, block, block],
        out_specs=[block, block, block],
        out_shape=out_shape,
        interpret=interpret,
    )(sel_arr, scal, reshape(xbar), reshape(gbar), reshape(pi), reshape(h))
    return x_new.reshape(n), pi_new.reshape(n), z_new.reshape(n)
