"""Pure-jnp oracle for the fused FedGiA update — the paper-faithful
UNROLLED iteration of eqs (12)-(14), plus the GD branch (15)-(17)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fedgia_update_ref(xbar, gbar, pi, h, sel, sigma, m, *, k0: int):
    """Same signature as the kernel; iterates the ADMM update k0 times."""
    xbar32 = xbar.astype(jnp.float32)
    g = gbar.astype(jnp.float32)
    pi0 = pi.astype(jnp.float32)
    d = 1.0 / (h.astype(jnp.float32) / m + sigma)

    def step(pi_c, _):
        x = xbar32 - d * (g + pi_c)  # eq. (12)
        pi_n = pi_c + sigma * (x - xbar32)  # eq. (13)
        return pi_n, x

    pi_k, xs = jax.lax.scan(step, pi0, None, length=k0)
    x_k = xs[-1]
    z_k = x_k + pi_k / sigma  # eq. (14)

    x_gd = xbar32  # eq. (15)
    pi_gd = -g  # eq. (16)
    z_gd = xbar32 - g / sigma  # eq. (17)

    pick = lambda a, b: jnp.where(sel, a, b)
    return (
        pick(x_k, x_gd).astype(xbar.dtype),
        pick(pi_k, pi_gd).astype(xbar.dtype),
        pick(z_k, z_gd).astype(xbar.dtype),
    )
