from repro.kernels.fedgia_update.kernel import (
    fedgia_update_batched_kernel,
    fedgia_update_batched_kernel_donated,
)
from repro.kernels.fedgia_update.ops import (
    fedgia_update,
    fedgia_update_flat,
    kernel_by_default,
)
from repro.kernels.fedgia_update.ref import fedgia_update_ref
