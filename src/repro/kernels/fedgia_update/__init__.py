from repro.kernels.fedgia_update.ops import fedgia_update
from repro.kernels.fedgia_update.ref import fedgia_update_ref
