"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage ships kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd public wrapper with an interpret flag for CPU
validation) and ref.py (pure-jnp oracle the tests assert against).

  fedgia_update   — the paper's per-round client update, eqs (12)-(17),
                    fused into one elementwise pass (DESIGN §6 B1/B2)
  flash_attention — blocked causal GQA attention (+ sliding window), the
                    prefill/train hot-spot
  rwkv6_scan      — RWKV-6 data-dependent-decay recurrence, chunked over
                    time with the state held in VMEM
"""
