"""Blocked causal GQA flash attention for TPU.

Grid (B, H, num_q_blocks, num_kv_blocks); the innermost kv dimension is
sequential ("arbitrary") so the running max / denominator / accumulator
live in VMEM scratch across kv steps — the streaming-softmax algorithm.
GQA is expressed in the BlockSpec index map: the kv block for query head h
is head h // group_size, so K/V are never materialised per-query-head.

VMEM budget per step (fp32): q (bq,hd) + k,v (bk,hd) + scores (bq,bk)
+ acc (bq,hd): with bq=bk=256, hd=128 that is ~0.7 MiB — comfortably
within a v5e core's VMEM while double-buffering.

Causal + optional sliding-window masking is computed from block indices;
fully-masked kv blocks are skipped via pl.when (no MXU work for the upper
triangle — ~2x prefill win).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x releases;
# accept either so the kernel imports on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, seq_len: int,
            window, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # visibility of this kv block for this q block
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 >= q_start - (window - 1)
        )

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))
        )  # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ()))
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_kernel(q, k, v, *, causal: bool = True, window=None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False):
    """q: (B,H,S,hd); k,v: (B,Kv,T,hd) with H % Kv == 0 and S == T.
    Returns (B,H,S,hd)."""
    B, H, S, hd = q.shape
    Kv, T = k.shape[1], k.shape[2]
    G = H // Kv
    scale = 1.0 / (hd**0.5)

    block_q = min(block_q, S)
    block_k = min(block_k, T)
    # pad sequence to block multiples (masked out via seq_len)
    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k

    grid = (B, H, Sp // block_q, Tp // block_k)
    q_spec = pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)
    )
    o_spec = pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0))

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale,
            block_q=block_q,
            block_k=block_k,
            seq_len=T,
            window=window,
            causal=causal,
        ),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
