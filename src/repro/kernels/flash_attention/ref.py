"""Full-softmax oracle for the flash kernel (materialises S x T scores)."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (B,H,S,hd); k,v: (B,Kv,T,hd). Returns (B,H,S,hd)."""
    B, H, S, hd = q.shape
    Kv, T = k.shape[1], k.shape[2]
    G = H // Kv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / (hd**0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)).astype(q.dtype)
