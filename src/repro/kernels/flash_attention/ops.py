"""Public wrapper for the flash attention kernel."""
from __future__ import annotations

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    use_kernel: bool = True, interpret: bool = False,
                    block_q: int = 256, block_k: int = 256):
    """q: (B,H,S,hd); k,v: (B,Kv,T,hd). Blocked streaming softmax."""
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
