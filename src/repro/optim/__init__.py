from repro.optim.optimizers import sgd, adam, apply_updates
from repro.optim.schedules import paper_lr, constant, cosine
