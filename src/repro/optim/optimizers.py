"""Minimal optimizer library (no external deps): SGD(+momentum) and Adam.

Used by the local solvers of the baselines and by the example drivers.
API mirrors optax: init(params) -> opt_state; update(grads, opt_state,
params) -> (updates, opt_state); apply_updates(params, updates).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def sgd(lr, momentum: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"mu": mu, "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step_lr = lr_fn(state["count"])
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(m.dtype), state["mu"], grads
            )
            upd = jax.tree.map(lambda m: -step_lr * m, mu)
            new = {"mu": mu, "count": state["count"] + 1}
        else:
            upd = jax.tree.map(lambda g: -step_lr * g, grads)
            new = {"mu": None, "count": state["count"] + 1}
        return upd, new

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        c = state["count"] + 1
        m = jax.tree.map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree.map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        mhat = jax.tree.map(lambda mm: mm / (1 - b1**c.astype(jnp.float32)), m)
        vhat = jax.tree.map(lambda vv: vv / (1 - b2**c.astype(jnp.float32)), v)
        step_lr = lr_fn(c)
        upd = jax.tree.map(
            lambda mm, vv: -step_lr * mm / (jnp.sqrt(vv) + eps), mhat, vhat
        )
        return upd, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)
