"""Learning-rate schedules. `paper_lr` is the paper's gamma_k(a) (§V.D)."""
from __future__ import annotations

import jax.numpy as jnp


def paper_lr(a: float):
    """gamma_k(a) = a / log2(k+2)."""

    def fn(count):
        return a / jnp.log2(count.astype(jnp.float32) + 2.0)

    return fn


def constant(a: float):
    return lambda count: a


def cosine(a: float, total: int, warmup: int = 0):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = jnp.minimum(c / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((c - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        return a * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))

    return fn
