"""Pytree arithmetic helpers used by all federated algorithms.

Every federated algorithm in this repo manipulates whole model states
(parameters, duals, control variates) as pytrees; these helpers keep that
code readable and fusion-friendly (jnp ops only, no python loops over
leaves at trace time beyond tree_map).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Pytree = object


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_mul(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.multiply, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha*x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_ones_like(a: Pytree) -> Pytree:
    return jax.tree.map(jnp.ones_like, a)


def tree_dot(a: Pytree, b: Pytree) -> jax.Array:
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sq_norm(a: Pytree) -> jax.Array:
    return tree_dot(a, a)


def tree_norm(a: Pytree) -> jax.Array:
    return jnp.sqrt(tree_sq_norm(a))


def tree_size(a: Pytree) -> int:
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_where(pred, a: Pytree, b: Pytree) -> Pytree:
    """Leaf-wise select; pred is a scalar (or broadcastable) bool array."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_mean_over_axis(a: Pytree, axis: int = 0) -> Pytree:
    return jax.tree.map(lambda x: jnp.mean(x, axis=axis), a)


def tree_stack(trees, axis: int = 0) -> Pytree:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=axis), *trees)


def tree_unstack(tree, axis: int = 0):
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[axis]
    return [
        jax.tree.unflatten(treedef, [jnp.take(l, i, axis=axis) for l in leaves])
        for i in range(n)
    ]


def tree_allclose(a: Pytree, b: Pytree, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.map(
        lambda x, y: bool(jnp.allclose(x, y, rtol=rtol, atol=atol)), a, b
    )
    return all(jax.tree.leaves(oks))
