"""Pytree arithmetic helpers used by all federated algorithms.

Every federated algorithm in this repo manipulates whole model states
(parameters, duals, control variates) as pytrees; these helpers keep that
code readable and fusion-friendly (jnp ops only, no python loops over
leaves at trace time beyond tree_map).

The `RavelSpec` family (`ravel_spec` / `RavelSpec.ravel` /
`RavelSpec.ravel_stacked` / `RavelSpec.unravel`) is the flat-buffer layout
the round engine's hot path runs on: the model pytree is flattened ONCE
per `run_rounds` call into a single lane-padded (N,) vector (client state:
one (m, N) buffer), every round's elementwise math and eq. (11)'s
all-reduce operate on that contiguous buffer, and the pytree is only
reconstructed at the gradient/metric/return boundaries. See
docs/engine.md#flat-buffer-round-state.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp

Pytree = object

# Debug-mode invariant checking: with REPRO_DEBUG_TAIL=1, every unravel
# asserts the lane-padded tail of the flat buffer is still exactly zero
# (the invariant eq. (11)'s norms and the Pallas kernel rely on). Off by
# default — the check inserts a host callback per unravel.
DEBUG_TAIL = os.environ.get("REPRO_DEBUG_TAIL", "0") not in ("", "0")


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_mul(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.multiply, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """alpha*x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a: Pytree) -> Pytree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_ones_like(a: Pytree) -> Pytree:
    return jax.tree.map(jnp.ones_like, a)


def tree_dot(a: Pytree, b: Pytree) -> jax.Array:
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.float32(0.0))


def tree_sq_norm(a: Pytree) -> jax.Array:
    return tree_dot(a, a)


def tree_norm(a: Pytree) -> jax.Array:
    return jnp.sqrt(tree_sq_norm(a))


def tree_size(a: Pytree) -> int:
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a: Pytree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_where(pred, a: Pytree, b: Pytree) -> Pytree:
    """Leaf-wise select; pred is a scalar (or broadcastable) bool array."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def tree_mean_over_axis(a: Pytree, axis: int = 0) -> Pytree:
    return jax.tree.map(lambda x: jnp.mean(x, axis=axis), a)


def tree_stack(trees, axis: int = 0) -> Pytree:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=axis), *trees)


def tree_unstack(tree, axis: int = 0):
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[axis]
    return [
        jax.tree.unflatten(treedef, [jnp.take(l, i, axis=axis) for l in leaves])
        for i in range(n)
    ]


def tree_allclose(a: Pytree, b: Pytree, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.map(
        lambda x, y: bool(jnp.allclose(x, y, rtol=rtol, atol=atol)), a, b
    )
    return all(jax.tree.leaves(oks))


# --------------------------------------------------------------------------
# Flat-buffer layout: ravel a model pytree once, run the round on the
# contiguous vector, unravel only at gradient/metric/return boundaries.
# --------------------------------------------------------------------------
LANES = 128  # TPU vector-register lane width; the flat buffer is padded to
# a multiple of it so the Pallas round kernel never re-pads on the hot path


@dataclasses.dataclass(frozen=True)
class RavelSpec:
    """Cached flatten layout for a model pytree.

    Records the treedef plus per-leaf shapes/dtypes/offsets into a single
    1-D buffer of ``size`` elements, lane-padded to ``padded_size``
    (``LANES``-multiple, zeros in the tail). The buffer dtype is the
    result-type promotion of the leaf dtypes, so an unravel->ravel round
    trip is exact (leaves are cast to a wider-or-equal dtype and back).

    Built by :func:`ravel_spec` (cached on (treedef, shapes, dtypes), so
    repeated `run_rounds` calls on the same model reuse one spec object
    and jit caches keyed on the spec hit).
    """

    treedef: jax.tree_util.PyTreeDef
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[jnp.dtype, ...]
    offsets: Tuple[int, ...]
    size: int
    padded_size: int
    dtype: jnp.dtype

    def ravel(self, tree: Pytree) -> jax.Array:
        """Pytree -> contiguous (padded_size,) vector (zero-padded tail)."""
        leaves = self.treedef.flatten_up_to(tree)
        flat = jnp.concatenate(
            [l.astype(self.dtype).reshape(-1) for l in leaves]
        )
        pad = self.padded_size - self.size
        return jnp.pad(flat, (0, pad)) if pad else flat

    def ravel_stacked(self, tree: Pytree) -> jax.Array:
        """Client-stacked pytree (leading axis m on every leaf) ->
        one contiguous (m, padded_size) buffer."""
        leaves = self.treedef.flatten_up_to(tree)
        m = leaves[0].shape[0]
        flat = jnp.concatenate(
            [l.astype(self.dtype).reshape(m, -1) for l in leaves], axis=1
        )
        pad = self.padded_size - self.size
        return jnp.pad(flat, ((0, 0), (0, pad))) if pad else flat

    def unravel(self, flat: jax.Array) -> Pytree:
        """(padded_size,) vector -> pytree (inverse of :meth:`ravel`)."""
        if DEBUG_TAIL:
            flat = self.check_zero_tail(flat)
        leaves = [
            jax.lax.slice_in_dim(flat, o, o + _size_of(s), axis=-1)
            .reshape(flat.shape[:-1] + s)
            .astype(d)
            for o, s, d in zip(self.offsets, self.shapes, self.dtypes)
        ]
        return self.treedef.unflatten(leaves)

    def unravel_stacked(self, flat: jax.Array) -> Pytree:
        """(m, padded_size) buffer -> client-stacked pytree."""
        return self.unravel(flat)

    def check_zero_tail(self, flat: jax.Array) -> jax.Array:
        """Debug assertion: the lane-padded tail of `flat` is exactly zero.

        Returns `flat` unchanged (so it can be spliced into traced code);
        the check itself runs as a host callback and raises on violation.
        Only called when REPRO_DEBUG_TAIL=1 — the default path never pays
        for it.
        """
        if self.padded_size == self.size or flat.shape[-1] != self.padded_size:
            return flat
        tail = jax.lax.slice_in_dim(
            flat, self.size, self.padded_size, axis=-1
        )
        jax.debug.callback(
            _raise_on_nonzero_tail, jnp.max(jnp.abs(tail)), self.size,
            self.padded_size,
        )
        return flat


def _size_of(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def _raise_on_nonzero_tail(maxabs, size, padded_size):
    if float(maxabs) != 0.0:
        raise AssertionError(
            f"RavelSpec zero-tail invariant violated: |tail|_max = "
            f"{float(maxabs)!r} in pad region [{int(size)}, "
            f"{int(padded_size)}) — an in-place flat-buffer write leaked "
            f"into the lane padding (this silently skews eq. (11) norms)"
        )


_SPEC_CACHE: dict = {}


def ravel_spec(tree: Pytree) -> RavelSpec:
    """Build (or fetch the cached) :class:`RavelSpec` for `tree`'s layout.

    The cache key is (treedef, shapes, dtypes): any two pytrees with the
    same structure share one spec object, so the engine's jit caches —
    which close over the spec — are reused across `run_rounds` calls."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    key = (treedef, shapes, dtypes)
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        sizes = [_size_of(s) for s in shapes]
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        padded = -(-off // LANES) * LANES
        spec = RavelSpec(
            treedef=treedef,
            shapes=shapes,
            dtypes=dtypes,
            offsets=tuple(offsets),
            size=off,
            padded_size=padded,
            dtype=jnp.result_type(*dtypes) if dtypes else jnp.dtype("float32"),
        )
        _SPEC_CACHE[key] = spec
    return spec


# --------------------------------------------------------------------------
# Active-set client store: a round touches only the packed tile of the
# clients the participation mask selected, gathered from / scattered back
# to the resident (m, padded_size) flat buffers at the round's boundaries.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ActiveSet:
    """The round's packed participant tile, derived from a dense mask.

    ``idx`` holds the (sorted) resident-store row ids of this round's
    participants, padded to the static ``capacity`` with the sentinel
    ``num_clients`` (one past the last row). Padding rows gather a
    clamped duplicate of the last resident row (finite garbage — never
    NaN), are zeroed out of every reduction via ``valid``, and are
    dropped on scatter. Because ``idx`` is ascending and zero rows are
    exact identities of a sum, packed reductions over the tile are
    BITWISE equal to the dense masked reductions over all m rows.

    ``capacity`` is static per run: a fixed-cardinality policy (uniform /
    weighted / cyclic) packs to exactly |C| rows; variable-cardinality
    sources (availability, wall-clock arrivals) pack to m rows — correct,
    but no smaller than dense (see docs/engine.md#active-set-client-store).

    ``tile_state`` (static) marks the HOST-OFFLOADED round
    (``run_rounds(store="offload")``): the per-client state buffers the
    round receives are already the pre-gathered (capacity, N) participant
    tiles — the engine gathered them from the host-resident store before
    entering the jit — so :meth:`gather_state` / :meth:`scatter_state`
    become the identity and the engine owns the host-side write-back.
    ``idx`` / ``valid`` / ``count`` / ``mask`` keep their REAL resident
    semantics in both modes: dense (m,)-shaped riders (staleness ages,
    aggregation weights) and the dense-layout aggregation scatter still
    address the true resident rows. See docs/engine.md#host-offloaded-store.

    ``packed`` (static) opts the round's eq. (11) into the fp-tolerance
    PACKED aggregation (``run_rounds(aggregate="packed")``): the
    aggregation sums the (capacity, N) tile directly instead of scattering
    back to the dense (m, N) layout first — O(capacity·N), ~1 ulp from the
    bitwise dense default. See docs/engine.md#packed-aggregation.
    """

    idx: jax.Array  # (capacity,) int32 rows into the resident store
    valid: jax.Array  # (capacity,) bool — False on padding rows
    count: jax.Array  # () float32 — number of participants (== mask sum)
    mask: jax.Array  # (m_local,) bool — the round's dense mask
    capacity: int = dataclasses.field(metadata=dict(static=True))
    num_clients: int = dataclasses.field(metadata=dict(static=True))
    tile_state: bool = dataclasses.field(default=False,
                                         metadata=dict(static=True))
    packed: bool = dataclasses.field(default=False,
                                     metadata=dict(static=True))

    def gather(self, buf: jax.Array) -> jax.Array:
        """Resident (m, ...) buffer -> packed (capacity, ...) tile."""
        return gather_rows(buf, self.idx)

    def scatter(self, buf: jax.Array, tile: jax.Array) -> jax.Array:
        """Write the packed tile back into its resident rows (padding
        rows carry the sentinel index and are dropped)."""
        return scatter_rows(buf, self.idx, tile)

    def gather_state(self, buf: jax.Array) -> jax.Array:
        """Per-client STATE accessor: resident (m, ...) buffer -> packed
        tile — or the identity under ``tile_state`` (the engine already
        gathered the tile from the host-resident store). Algorithms must
        route their `flat_client_keys` reads through this instead of
        :meth:`gather`, which keeps resident row semantics for dense
        (m,)-shaped riders in both modes."""
        return buf if self.tile_state else self.gather(buf)

    def scatter_state(self, buf: jax.Array, tile: jax.Array) -> jax.Array:
        """Per-client STATE write-back twin of :meth:`gather_state`: under
        ``tile_state`` the updated tile is returned as-is (the engine
        scatters it into the host-resident rows outside the jit), else
        the ordinary resident-row scatter."""
        return tile if self.tile_state else self.scatter(buf, tile)

    def gather_tree(self, tree: Pytree) -> Pytree:
        """Gather every leaf's active rows (e.g. the per-client batch).
        Routed through :meth:`gather_state`: the host-offloaded engine
        pre-gathers the batch tile with the state tiles."""
        return jax.tree.map(self.gather_state, tree)

    def zero_invalid(self, tile: jax.Array) -> jax.Array:
        """Zero the padding rows of a (capacity, ...) tile so reductions
        over the tile match the dense masked reductions bitwise."""
        v = self.valid.reshape(self.valid.shape + (1,) * (tile.ndim - 1))
        return jnp.where(v, tile, jnp.zeros_like(tile))


jax.tree_util.register_dataclass(
    ActiveSet,
    data_fields=["idx", "valid", "count", "mask"],
    meta_fields=["capacity", "num_clients", "tile_state", "packed"],
)


def make_active_set(mask: jax.Array, capacity: int, *,
                    tile_state: bool = False,
                    packed: bool = False) -> ActiveSet:
    """Pack a dense (m,) participation mask into an :class:`ActiveSet`.

    ``capacity`` must upper-bound the mask's population count (the engine
    derives it from the policy's fixed cardinality, or uses m); overflow
    would silently drop participants, so callers own that invariant.
    ``tile_state`` / ``packed`` set the static store/aggregation modes
    (see the :class:`ActiveSet` docstring).
    """
    m = mask.shape[0]
    (idx,) = jnp.nonzero(mask, size=capacity, fill_value=m)
    idx = idx.astype(jnp.int32)
    return ActiveSet(
        idx=idx,
        valid=idx < m,
        count=jnp.sum(mask.astype(jnp.float32)),
        mask=mask,
        capacity=capacity,
        num_clients=m,
        tile_state=tile_state,
        packed=packed,
    )


def gather_rows(buf: jax.Array, idx: jax.Array) -> jax.Array:
    """Row gather with clamped out-of-range indices: padding rows read a
    duplicate of the last resident row (finite, deterministic) instead of
    producing NaN, and are masked/dropped downstream."""
    return jnp.take(buf, idx, axis=0, mode="clip")


def scatter_rows(buf: jax.Array, idx: jax.Array, rows: jax.Array) -> jax.Array:
    """Inverse of :func:`gather_rows`: write packed rows back into the
    resident buffer; sentinel (out-of-range) indices are dropped. Under
    buffer donation XLA updates the resident store in place."""
    return buf.at[idx].set(rows, mode="drop")


# --- host-resident placement for run_rounds(store="offload") -----------
#
# The offloaded store keeps the resident (m, N) client buffers in HOST
# memory and only moves (capacity, N) participant tiles to the device
# each round. Placement preference: pinned host memory of the default
# device (sharding memory_kind="pinned_host", zero-copy DMA on TPU/GPU)
# when the backend both accepts it AND can run the row gather/scatter on
# it; otherwise the CPU backend's device. On a CPU-only process the two
# coincide and every transfer below is a no-op.

_HOST_PLACEMENT = None


def host_placement():
    """The device/sharding host-resident offload buffers are committed
    to. Probed once per process; the probe runs the exact ops the
    offload store needs (row take / indexed set), so a backend that
    merely *stores* pinned-host arrays but cannot compute on them falls
    back to the CPU device."""
    global _HOST_PLACEMENT
    if _HOST_PLACEMENT is not None:
        return _HOST_PLACEMENT
    placement = None
    if jax.default_backend() != "cpu":
        try:
            sharding = jax.sharding.SingleDeviceSharding(
                jax.devices()[0], memory_kind="pinned_host")
            probe = jax.device_put(jnp.zeros((2, 2), jnp.float32), sharding)
            idx = jax.device_put(jnp.zeros((1,), jnp.int32), sharding)
            out = probe.at[idx].set(
                jnp.take(probe, idx, axis=0, mode="clip"), mode="drop")
            jax.block_until_ready(out)
            placement = sharding
        except Exception:
            placement = None
    if placement is None:
        placement = jax.local_devices(backend="cpu")[0]
    _HOST_PLACEMENT = placement
    return placement


def _demote_to_cpu():
    """Permanently demote the process-wide host placement to the CPU
    backend (the always-works path the init-time probe falls back to)."""
    global _HOST_PLACEMENT
    _HOST_PLACEMENT = jax.local_devices(backend="cpu")[0]
    return _HOST_PLACEMENT


def host_put(x) -> jax.Array:
    """Commit an array to the offload store's host placement.

    Hardened against MID-RUN transfer failures: the pinned-host pool can
    exhaust or the DMA path can error long after the init-time probe in
    :func:`host_placement` succeeded (e.g. another process grabbed the
    pinned pool, or a transient driver hiccup). A failed transfer is
    retried once with a warning; a second failure demotes the placement
    to the CPU backend for the remainder of the process instead of
    crashing the run — gather/scatter semantics are identical there
    (same clip/drop row ops, bit-identical values), only the transfer
    path is slower.
    """
    placement = host_placement()
    try:
        return jax.device_put(x, placement)
    except Exception as exc:  # XlaRuntimeError has no stable subclass
        warnings.warn(
            "host_put: transfer to the offload host placement failed "
            f"({type(exc).__name__}: {exc}); retrying once",
            RuntimeWarning, stacklevel=2)
    try:
        return jax.device_put(x, placement)
    except Exception as exc:
        if not isinstance(placement, jax.sharding.Sharding):
            # Already on the CPU-device fallback: nothing left to demote
            # to — this is a real error, surface it.
            raise
        warnings.warn(
            "host_put: pinned-host transfer failed twice "
            f"({type(exc).__name__}: {exc}); falling back to the CPU "
            "backend for the remainder of the run",
            RuntimeWarning, stacklevel=2)
        return jax.device_put(x, _demote_to_cpu())


def host_put_tree(tree: Pytree) -> Pytree:
    return jax.tree.map(host_put, tree)


class OffloadStore:
    """Host-resident flat client buffers for ``run_rounds(store="offload")``.

    Holds the per-client ``flat_client_keys`` buffers (z/π/h, λ, cᵢ, EF
    residuals) committed to :func:`host_placement`. Gather/scatter reuse
    the exact :func:`gather_rows` / :func:`scatter_rows` semantics of the
    device-resident active store (clip reads, drop writes) — pure data
    movement, so the round tiles carry bit-identical values and the
    offloaded store is bitwise-equal to ``store="active"``. See
    docs/engine.md#host-offloaded-store.
    """

    def __init__(self, buffers: dict):
        self.buffers = {k: host_put(v) for k, v in buffers.items()}

    def gather_tiles(self, idx: jax.Array) -> dict:
        """(capacity,) host row ids -> {key: (capacity, ...) host tile}."""
        return {k: gather_rows(b, idx) for k, b in self.buffers.items()}

    def scatter_tiles(self, idx: jax.Array, tiles: dict) -> None:
        """Write the round's updated tiles back into the resident rows."""
        for k, rows in tiles.items():
            self.buffers[k] = scatter_rows(self.buffers[k], idx,
                                           host_put(rows))

    @property
    def nbytes(self) -> int:
        return sum(int(b.nbytes) for b in self.buffers.values())
