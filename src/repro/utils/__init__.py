from repro.utils import pytree
from repro.utils.logging import get_logger
