"""FedGiA hyper-parameter policies: sigma and H_i (paper Remark IV.1 / Table III).

Theory requirements (Lemma IV.1): sigma >= 6 r / m and 0 <= H_i <= r_i I.
  * sigma = t * r / m with t from Table III (t >= 6 gives the guaranteed
    regime; the paper uses smaller t in practice and still converges).
  * H policies:
      scalar   — H_i = r_hat * I           (always theory-compliant)
      diag_ema — per-parameter diagonal curvature proxy from gradient
                 magnitudes, clipped to [0, r_hat]  (compliant by Remark IV.1)
      gram     — H_i = Gram matrix of the client data (linear models only;
                 paper's FedGiA_G)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import pytree as pt

EMA_BETA = 0.9


def sigma_from(t: float, r: float, m: int):
    return t * r / m


def estimate_lipschitz(loss_fn, params, batch, key, probes: int = 4, eps: float = 1e-2):
    """r_hat = max over random probes of ||g(x+d) - g(x)|| / ||d||."""
    g0 = jax.grad(lambda p: loss_fn(p, batch)[0])(params)

    def probe(k):
        d = jax.tree.map(
            lambda a, kk: eps * jax.random.normal(kk, a.shape, jnp.float32),
            params,
            _split_like(k, params),
        )
        p2 = pt.tree_add(params, jax.tree.map(lambda x, a: x.astype(a.dtype), d, params))
        g1 = jax.grad(lambda p: loss_fn(p, batch)[0])(p2)
        num = pt.tree_norm(pt.tree_sub(g1, g0))
        den = pt.tree_norm(d)
        return num / jnp.maximum(den, 1e-12)

    keys = jax.random.split(key, probes)
    vals = jnp.stack([probe(k) for k in keys])
    return jnp.maximum(vals.max(), 1e-8)


def _split_like(key, tree):
    leaves, treedef = jax.tree.flatten(tree)
    keys = list(jax.random.split(key, len(leaves)))
    return jax.tree.unflatten(treedef, keys)


def update_diag_h(h, gbar, r_hat, m: int):
    """EMA diagonal curvature proxy, clipped to [0, r_hat] (Remark IV.1).

    gbar is the scaled gradient (1/m) grad f_i; rescale to grad f_i before
    normalising so the proxy is invariant to m.
    """
    from repro.core import api

    g2 = jax.tree.map(lambda g: jnp.square(g.astype(jnp.float32) * m), gbar)
    gmax = api.client_scalar_max(
        jax.tree.reduce(
            jnp.maximum,
            jax.tree.map(lambda a: a.max(), g2),
            jnp.float32(1e-30),
        )
    )
    h_new = jax.tree.map(
        lambda hh, gg: jnp.clip(
            EMA_BETA * hh + (1 - EMA_BETA) * (r_hat * gg / gmax), 0.0, r_hat
        ),
        h,
        g2,
    )
    return h_new
