"""On-device client fault injection and server-side screening.

FedGiA's convergence story (paper Thm. 2 / Assumption 1) holds for
*well-behaved* partial participation: every selected client returns a
finite, on-time update. This module is the adversarial-reality layer —
a keyed :class:`FaultModel` corrupts the flat (rows, N) contribution
buffer ON DEVICE just before eq. (11)'s aggregation, and a
:class:`Screening` stage folds a per-row finite check + norm clip into
the participation mask so the server aggregates only what survives.

Fault taxonomy (``FAULT_KINDS``):

  * ``crash``   — the client never uploads: its row leaves the round's
    aggregation mask (and is zeroed, so the weighted numerators that
    MULTIPLY by the mask-folded weights never see its bits).
  * ``nan`` / ``inf`` — wire/accelerator corruption: the row's payload
    columns are overwritten with non-finite values.
  * ``explode`` — a diverged local solve: the row is scaled by
    ``FaultSpec.scale`` (finite, so only the norm clip catches it).
  * ``replay`` — a confused client re-sends its PREVIOUS successful
    upload (the ``fault_prev`` carry buffer, engine-created like the
    compression EF residual and riding ``flat_client_keys``).

Determinism: the draw is STATELESS-keyed — per round the base key is
``fold_in(PRNGKey(seed), round)`` and each client folds in its GLOBAL
row id (the `api._compress_row_ids` convention), so the same clients
fault in the same rounds whether the run is unsharded, client-sharded,
scan or legacy, dense / active / offload — and across checkpoint
resume, which never has to save fault state beyond ``fault_prev``.

Screening preserves the one-psum invariant: the screened mask and clip
scale are computed shard-locally BEFORE the collective and ride the
existing mask/weight riders of `api.flat_round_aggregate[_active]`, so
a screening-enabled sharded round still lowers to exactly {1 AR}
(barrier) / {1 RS, 1 AG} (overlap) — HLO-asserted in tests/test_faults.py.
With ``faults=None`` and ``screening=None`` every round path is
STRUCTURALLY unchanged (bitwise the fault-free engine).

See docs/faults.md for the full semantics (quorum, watchdog, resume).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

FAULT_KINDS = ("crash", "nan", "inf", "explode", "replay")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault process: ``kind`` with per-client per-round probability
    ``rate``; ``scale`` is the multiplier of ``explode`` rows."""

    kind: str
    rate: float
    scale: float = 1e6

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; pick from {FAULT_KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate}")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """A composite per-client fault process, drawn on-device each round.

    Static round-fn configuration (like the compressor): the model holds
    no traced state — the draw is keyed off ``(seed, round, row id)``
    alone — except the replay buffer ``fault_prev``, which the engine
    creates and threads through the carry exactly like the EF residual.
    """

    num_clients: int
    specs: Tuple[FaultSpec, ...]
    seed: int = 0

    def __post_init__(self):
        kinds = [s.kind for s in self.specs]
        if len(set(kinds)) != len(kinds):
            raise ValueError(f"duplicate fault kinds in {kinds}")

    @property
    def needs_prev(self) -> bool:
        """True when the model replays — the engine then creates the
        (m, N) ``fault_prev`` carry buffer."""
        return any(s.kind == "replay" for s in self.specs)

    def draw(self, round_idx: jax.Array, row_ids: jax.Array) -> dict:
        """Per-client fault indicators for this round: {kind: (rows,) bool}.

        ``row_ids`` are GLOBAL client ids (uint32) so sharded/packed rows
        draw exactly the dense rows' faults."""
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                  jnp.asarray(round_idx, jnp.uint32))
        hits = {}
        for j, s in enumerate(self.specs):
            kkey = jax.random.fold_in(base, jnp.uint32(j))
            keys = jax.vmap(lambda r, k=kkey: jax.random.fold_in(k, r))(
                row_ids.astype(jnp.uint32))
            u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)
            hits[s.kind] = u < jnp.float32(s.rate)
        return hits

    def apply(self, contrib: jax.Array, mask: Optional[jax.Array],
              prev: Optional[jax.Array], round_idx: jax.Array,
              row_ids: jax.Array, *, payload_cols: Optional[int] = None):
        """Corrupt the decoded (rows, N) upload just before aggregation.

        Order: replay (row <- last successful upload), explode (scale),
        nan, inf, then crash — a crashed row leaves the arrival mask AND
        is zeroed (the weighted aggregation paths multiply, and 0*NaN
        would poison the numerator). ``payload_cols`` bounds the nan/inf
        overwrite to the real model columns so the flat buffers'
        zero-padding-tail invariant survives injection.

        Returns ``(corrupt, arrive, prev')`` where ``arrive`` is the
        post-crash participation mask and ``prev'`` the advanced replay
        buffer (the HONEST pre-corruption upload of every arriving row —
        what the client actually computed and sent; None when the model
        carries no replay buffer).
        """
        hits = self.draw(round_idx, row_ids)
        honest = contrib
        out = contrib
        if prev is not None and "replay" in hits:
            out = jnp.where(hits["replay"][:, None], prev.astype(out.dtype),
                            out)
        if "explode" in hits:
            scale = next(s.scale for s in self.specs if s.kind == "explode")
            out = jnp.where(hits["explode"][:, None],
                            out * jnp.asarray(scale, out.dtype), out)
        cols = contrib.shape[-1] if payload_cols is None else payload_cols
        col_ok = jnp.arange(contrib.shape[-1]) < cols
        for kind, val in (("nan", jnp.nan), ("inf", jnp.inf)):
            if kind in hits:
                bad = jnp.logical_and(hits[kind][:, None], col_ok[None, :])
                out = jnp.where(bad, jnp.asarray(val, out.dtype), out)
        crash = hits.get("crash")
        if crash is None:
            arrive = (jnp.ones(contrib.shape[0], bool) if mask is None
                      else mask)
        else:
            arrive = (~crash if mask is None
                      else jnp.logical_and(mask, ~crash))
        out = jnp.where(arrive[:, None], out, jnp.zeros_like(out))
        prev_new = None
        if prev is not None:
            prev_new = jnp.where(arrive[:, None], honest.astype(prev.dtype),
                                 prev)
        return out, arrive, prev_new


@dataclasses.dataclass(frozen=True)
class Screening:
    """Server-side upload screening: rows with any non-finite entry are
    dropped from the aggregation mask (and zeroed, so no non-finite value
    ever reaches eq. (11)'s psum); finite rows whose l2 norm exceeds
    ``clip_norm`` are scaled down onto the clip ball."""

    clip_norm: Optional[float] = None

    def __post_init__(self):
        if self.clip_norm is not None and self.clip_norm <= 0:
            raise ValueError(f"clip_norm must be > 0, got {self.clip_norm}")


def screen_rows(contrib: jax.Array, mask: Optional[jax.Array],
                screening: Screening):
    """Apply :class:`Screening` to a (rows, N) contribution buffer.

    Returns ``(contrib', smask)`` with ``smask`` ⊆ ``mask`` (the screened
    participation mask) and every row of ``contrib'`` finite — screened-out
    rows are exact zeros, clipped rows scaled by ``clip/||row||``. All
    shard-local: the caller's aggregation collective is unchanged."""
    finite = jnp.all(jnp.isfinite(contrib), axis=-1)
    smask = finite if mask is None else jnp.logical_and(mask, finite)
    out = jnp.where(smask[:, None], contrib, jnp.zeros_like(contrib))
    if screening.clip_norm is not None:
        nrm = jnp.sqrt(jnp.sum(
            (out * out).astype(jnp.float32), axis=-1))
        c = jnp.float32(screening.clip_norm)
        scale = jnp.where(nrm > c, c / jnp.maximum(nrm, jnp.float32(1e-30)),
                          jnp.float32(1.0))
        out = out * scale[:, None].astype(out.dtype)
    return out, smask


def make_faults(kinds: Sequence[str], rates: Sequence[float], *,
                num_clients: int, seed: int = 0,
                scale: float = 1e6) -> Optional[FaultModel]:
    """Build a :class:`FaultModel` from parallel kind/rate lists (the CLI
    surface: ``--faults crash,nan --fault-rate 0.1,0.01``). A single rate
    broadcasts over all kinds; an empty kind list returns None (no
    faults, structurally fault-free rounds)."""
    kinds = [k for k in kinds if k]
    if not kinds:
        return None
    rates = list(rates)
    if len(rates) == 1 and len(kinds) > 1:
        rates = rates * len(kinds)
    if len(rates) != len(kinds):
        raise ValueError(
            f"--fault-rate needs 1 or {len(kinds)} values, got {len(rates)}")
    specs = tuple(FaultSpec(k, float(r), scale) for k, r in zip(kinds, rates))
    return FaultModel(num_clients=num_clients, specs=specs, seed=seed)
