"""FedGiA — the paper's Algorithm 1, as a composable JAX module.

One communication round = one jitted call:

  1. aggregate   x̄ = (1/m) Σ z_i              (eq. 11 — ONE all-reduce)
  2. grads       ḡ_i = (1/m) ∇f_i(x̄)          (computed ONCE per round)
  3. split       C ~ alpha·m clients            (selection.py; an
     engine-provided participation mask, when present, IS this split)
  4. ADMM branch (i ∈ C):  k0 iterations of eqs (12)-(14)
     GD branch   (i ∉ C):  eqs (15)-(17), once
  5. state carries (z_i, π_i) per client; x_i = z_i − π_i/σ is derived.

Because x̄ and ḡ_i are FIXED within a round, the ADMM iteration is affine
in π_i:  π ← (1−σD)π − σDḡ  with D = (H/m + σI)^{-1}.  `collapsed=True`
(beyond-paper, DESIGN §6 B1) evaluates the k0-step recursion in closed form

    π^{k0} = a^{k0} (π⁰ + ḡ) − ḡ,      a = 1 − σD
    x^{k0} = x̄ − D a^{k0−1} (π⁰ + ḡ)
    z^{k0} = x^{k0} + π^{k0}/σ

— exactly equal to the unrolled loop (property-tested), with ~k0× less
elementwise HBM traffic. `collapsed=False` runs the paper-faithful
`lax.scan`. H policies: scalar r̂·I, clipped diagonal EMA, or the client
Gram matrix (paper's FedGiA_G, linear models).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.config import FedConfig
from repro.core import api, compress, hparams, selection
from repro.core.api import LossFn, broadcast_clients, per_client_value_and_grad
from repro.kernels.fedgia_update import fedgia_update_flat, kernel_by_default
from repro.utils import pytree as pt


class FedGiA:
    name = "fedgia"
    # leaves with a leading client axis — what the engine shards over `data`
    # ("ef" = the error-feedback residual buffer, present only under a
    # lossy compressor with error_feedback — absent keys cost nothing)
    client_state_keys = ("z", "pi", "h", "gram_chol", "ef", "fault_prev")
    # model-shaped state the flat engine ravels into (m, N) / (N,) buffers
    # (gram_chol is client-stacked but not model-shaped: it stays a
    # (m, n, n) factor either way)
    flat_client_keys = ("z", "pi", "h", "ef", "fault_prev")
    flat_global_keys = ("x",)
    # FedGiA's GD branch (eqs. 15-17) rewrites EVERY non-selected client's
    # state from its fresh gradient each round, so the round's working set
    # is the whole population by construction — the active tile is (m, N)
    # and the store degenerates to dense (see round_flat_active).
    active_tile = "population"

    def __init__(self, fed: FedConfig, loss_fn: LossFn, model=None):
        self.fed = fed
        self.loss_fn = loss_fn
        self.model = model
        self._vg = per_client_value_and_grad(loss_fn)
        # stale-x̄ rounds: each client's gradient at its OWN anchor
        self._vg_per_anchor = api.per_client_value_and_grad_stacked(loss_fn)

    # ------------------------------------------------------------------ init
    def init(self, params0, rng, init_batch=None) -> Dict[str, Any]:
        fed = self.fed
        m = fed.num_clients
        sdt = jnp.dtype(fed.state_dtype)
        r = jnp.float32(fed.lipschitz)
        if fed.auto_lipschitz and init_batch is not None:
            per_client = jax.vmap(
                lambda b, k: hparams.estimate_lipschitz(
                    self.loss_fn, params0, b, k
                ),
                in_axes=(0, 0),
            )
            r = per_client(init_batch, jax.random.split(rng, m)).max()
        elif self.model is not None and hasattr(self.model, "lipschitz") and init_batch is not None:
            r = jax.vmap(self.model.lipschitz)(init_batch).max()

        # paper §V.B: x_i^0 = pi_i^0 = 0; we start from params0 instead of 0
        # so the same init works for NNs (paper setting recovered with
        # params0 = zeros).
        xc = broadcast_clients(pt.tree_cast(params0, sdt), m)
        pi = pt.tree_zeros_like(xc)
        z = xc  # z = x + pi/sigma with pi = 0

        state: Dict[str, Any] = {
            "x": pt.tree_cast(params0, sdt),
            "z": z,
            "pi": pi,
            "sigma": jnp.float32(hparams.sigma_from(fed.sigma_t, r, m)),
            "r": r,
            "round": jnp.zeros((), jnp.int32),
            "rng": rng,
        }
        if fed.h_policy == "diag_ema":
            state["h"] = jax.tree.map(
                lambda a: jnp.full(a.shape, r, jnp.float32), xc
            )
        elif fed.h_policy == "gram":
            assert self.model is not None and hasattr(self.model, "gram"), (
                "gram H policy requires a model exposing .gram(batch) "
                "(linear models, paper Table III)"
            )
            assert init_batch is not None
            H = jax.vmap(self.model.gram)(init_batch)  # (m, n, n)
            sig = hparams.sigma_from(fed.sigma_t, r, m)
            n = H.shape[-1]
            A = H / m + sig * jnp.eye(n)
            state["gram_chol"] = jax.vmap(lambda a: jsl.cho_factor(a)[0])(A)
        return state

    # ------------------------------------------------------------- internals
    def _apply_Dinv(self, state, v):
        """v -> (H/m + sigma I)^{-1} v, stacked over clients."""
        fed, m = self.fed, self.fed.num_clients
        sigma = state["sigma"]
        if fed.h_policy == "gram":
            chol = state["gram_chol"]
            flat = v["x"]  # (m, n) — gram restricted to linear models
            out = jax.vmap(lambda c, b: jsl.cho_solve((c, False), b))(chol, flat)
            return {"x": out}
        h = state.get("h")
        if h is None:  # scalar policy: H = r I
            return jax.tree.map(lambda g: g / (state["r"] / m + sigma), v)
        return jax.tree.map(lambda g, hh: g / (hh / m + sigma), v, h)

    def _admm_branch(self, state, xbar_c, gbar):
        """k0 iterations of eqs (12)-(14) for ALL clients (masked later)."""
        fed = self.fed
        sigma = state["sigma"]
        pi0 = state["pi"]
        base = pt.tree_add(pi0, gbar)  # pi^0 + g

        if fed.collapsed and fed.h_policy != "gram":
            m = fed.num_clients
            h = state.get("h")

            def leafwise(g, p0, hh):
                d = 1.0 / (hh / m + sigma)
                a = 1.0 - sigma * d
                b = p0 + g
                ak1 = a ** (fed.k0 - 1)
                pi_new = ak1 * a * b - g
                x_new = -d * ak1 * b  # relative to xbar
                return x_new, pi_new

            if h is None:
                r = state["r"]
                hs = jax.tree.map(lambda g: r, gbar)
            else:
                hs = h
            xn_rel = jax.tree.map(lambda g, p0, hh: leafwise(g, p0, hh)[0], gbar, pi0, hs)
            pi_new = jax.tree.map(lambda g, p0, hh: leafwise(g, p0, hh)[1], gbar, pi0, hs)
            x_new = pt.tree_add(xbar_c, xn_rel)
        else:
            # paper-faithful k0-step iteration. Python loop (k0 is small):
            # keeps XLA cost_analysis exact (scan bodies are counted once).
            pi_after = pi0
            for _ in range(fed.k0 - 1):
                x = pt.tree_sub(
                    xbar_c, self._apply_Dinv(state, pt.tree_add(gbar, pi_after))
                )
                pi_after = pt.tree_axpy(sigma, pt.tree_sub(x, xbar_c), pi_after)
            x_new = pt.tree_sub(
                xbar_c, self._apply_Dinv(state, pt.tree_add(gbar, pi_after))
            )
            pi_new = pt.tree_axpy(sigma, pt.tree_sub(x_new, xbar_c), pi_after)

        z_new = pt.tree_axpy(1.0 / sigma, pi_new, x_new)
        return x_new, pi_new, z_new

    def _apply_Dinv_flat(self, state, v, spec):
        """Flat-buffer (m, N) twin of `_apply_Dinv` — same op order, so the
        unrolled flat iteration is bitwise the unrolled pytree iteration
        on the raveled layout."""
        fed, m = self.fed, self.fed.num_clients
        sigma = state["sigma"]
        if fed.h_policy == "gram":
            chol = state["gram_chol"]
            n = spec.size  # gram is restricted to single-leaf linear models
            flat = v[:, :n]
            out = jax.vmap(lambda c, b: jsl.cho_solve((c, False), b))(chol, flat)
            pad = v.shape[1] - n
            return jnp.pad(out, ((0, 0), (0, pad))) if pad else out
        h = state.get("h")
        if h is None:  # scalar policy: H = r I
            return v / (state["r"] / m + sigma)
        return v / (h / m + sigma)

    def _admm_branch_flat(self, state, xbar_c, gbar, spec):
        """k0 iterations of eqs (12)-(14) on the flat (m, N) buffer.

        Mirrors `_admm_branch` operation-for-operation (division by
        (h/m + sigma), add-of-negated relative step, axpy z), so the
        non-kernel flat branch is bitwise the pytree branch on the
        raveled layout."""
        fed = self.fed
        sigma = state["sigma"]
        pi0 = state["pi"]

        if fed.collapsed and fed.h_policy != "gram":
            m = fed.num_clients
            h = state.get("h")
            hh = state["r"] if h is None else h
            d = 1.0 / (hh / m + sigma)
            a = 1.0 - sigma * d
            b = pi0 + gbar
            ak1 = a ** (fed.k0 - 1)
            pi_new = ak1 * a * b - gbar
            x_new = xbar_c + (-d * ak1 * b)
        else:
            pi_after = pi0
            for _ in range(fed.k0 - 1):
                x = xbar_c - self._apply_Dinv_flat(state, gbar + pi_after,
                                                   spec)
                pi_after = sigma * (x - xbar_c) + pi_after
            x_new = xbar_c - self._apply_Dinv_flat(state, gbar + pi_after,
                                                   spec)
            pi_new = sigma * (x_new - xbar_c) + pi_after

        z_new = (1.0 / sigma) * pi_new + x_new
        return x_new, pi_new, z_new

    def _use_kernel(self) -> bool:
        """Route the collapsed diagonal-H branch through the batched Pallas
        kernel? `FedConfig.use_kernel`: None = auto by backend."""
        fed = self.fed
        if not fed.collapsed or fed.h_policy == "gram":
            return False
        if fed.use_kernel is None:
            return kernel_by_default()
        return fed.use_kernel

    # ------------------------------------------------------------ flat round
    def round_flat(self, state, batch, spec, mask=None, stale=None,
                   compressor=None, donate_kernel=False,
                   faults=None, screening=None):
        """One communication round on the FLAT client-state buffer.

        Same contract as `round`, but `state["z"]` / `state["pi"]` /
        `state["h"]` are one (m, N) buffer each (`state["x"]` is (N,)),
        raveled once by the engine (`utils.pytree.RavelSpec`). Eq. (11)
        is a mean over a single contiguous array — under sharding the
        round's ONE model-size all-reduce — and the ADMM/GD branch is a
        single fused elementwise pass: the batched Pallas
        `kernels/fedgia_update` kernel when `FedConfig.use_kernel`
        resolves true (fp-equivalent), else a jnp twin that is bitwise
        the pytree branch on the raveled layout. The pytree is
        reconstructed only for the per-client gradient evaluation and the
        `grad_sq_norm` metric boundary (docs/engine.md).

        `compressor` (core/compress.py): eq. (11) aggregates the DECODED
        uploads C(z_i [+ e_i]) instead of the raw z_i — FedGiA's uplink
        is the whole population's z every round (every client's state is
        rewritten, `active_tile="population"`), so the codec runs on all
        m rows and, with error feedback, every residual advances every
        round. Decompress-before-reduce: the fp32 decode enters the same
        one-psum mean.

        Overlap (`run_rounds(overlap="scatter")`): when the engine seeds
        `state["ovl_shard"]`, eq. (11)'s collective is SPLIT across the
        round boundary — the round top all-gathers last round's
        reduce-scattered consensus shard (`api.flat_overlap_consensus`)
        instead of computing the mean, and the round end reduce-scatters
        the FRESH z upload (`api.flat_overlap_aggregate`), so the wire
        hides behind the next round's local compute. Value-preserving:
        x̄ᵗ is the same mean either way (bitwise when unsharded); the
        codec key at the round end is round t+1's barrier key, so only
        the round-0 slot seed + the error-feedback sequence shift for
        lossy codecs (docs/engine.md#overlapped-collectives).

        `donate_kernel=True` routes the kernel branch through the donated
        Pallas call: the (m, N) anchor/gradient/multiplier buffers alias
        the outputs and update in place (no extra model-size temp).
        """
        fed = self.fed
        m = fed.num_clients
        m_local = api.local_client_count(m)
        sdt = jnp.dtype(fed.state_dtype)
        sigma = state["sigma"]
        assert stale is None or mask is not None, (
            "stale-x̄ rounds need the engine arrival mask"
        )

        # (1) aggregation — eq. (11) as ONE contiguous model-size mean
        # (under client sharding: the round's single model-size psum).
        # Under a codec the mean is over the decoded uploads. Overlapped
        # rounds instead all-gather the consensus shard reduce-scattered
        # at the END of the previous round — the deferred half of the
        # split collective.
        ef_new = None
        fprev_new = None
        n_scr = None
        hardened = faults is not None or screening is not None
        ovl = state.get("ovl_shard")
        if ovl is not None:
            xbar = api.flat_overlap_consensus(ovl)[0]
        else:
            z_up = state["z"]
            if compressor is not None:
                ef = state.get("ef") if compressor.error_feedback else None
                z_up, ef_new = api.compress_upload(
                    compressor, z_up, ef, spec,
                    key=compress.round_key(state["rng"], state["round"]))
            # faults/screening (core/faults.py): FedGiA's upload is the
            # whole population's z, so the screened mask starts from None
            # (all m rows) and eq. (11) becomes the mean over the rows
            # that arrived finite — same ONE psum, mask/count as riders.
            sc_mask = None
            if hardened:
                z_up, sc_mask, fprev_new, n_scr = api.harden_upload(
                    z_up, None, spec, faults=faults, screening=screening,
                    fault_prev=state.get("fault_prev"),
                    round_idx=state["round"])
            xbar = api.client_mean(z_up, mask=sc_mask,
                                   weights=api.stale_weights(stale))

        # (3) client selection — identical rng stream to the pytree round.
        rng, sel_key = jax.random.split(state["rng"])
        if mask is None:
            sel = api.local_client_slice(
                selection.selection_mask(
                    jax.random.fold_in(sel_key, state["round"]), m, fed.alpha
                )
            )
        else:
            sel = mask

        # (2) per-client gradient — the one boundary that unravels: the
        # loss is a pytree function of the model, everything around it
        # stays flat.
        cast = (
            (lambda t: pt.tree_cast(t, self.model.dtype))
            if self.model is not None and hasattr(self.model, "dtype")
            else (lambda t: t)
        )
        if stale is None or stale.always_fresh:
            if stale is not None:
                xbar_c, stale = api.stale_xbar_view(stale, xbar, sel)
            else:
                xbar_c = broadcast_clients(xbar, m_local)
            losses, grads = self._vg(cast(spec.unravel(xbar)), batch)
        else:
            xbar_c, stale = api.stale_xbar_view(stale, xbar, sel)
            losses, grads = self._vg_per_anchor(
                cast(spec.unravel_stacked(xbar_c)), batch)
        gbar = spec.ravel_stacked(
            pt.tree_cast(pt.tree_scale(grads, 1.0 / m), sdt))  # ḡ_i (m, N)

        # (4) both branches + masked combine, one fused elementwise pass
        if self._use_kernel():
            h = state.get("h")
            if h is None:
                h = jnp.broadcast_to(state["r"], gbar.shape)
            x_new, pi_new, z_new = fedgia_update_flat(
                xbar_c, gbar, state["pi"], h, sel, sigma, m,
                k0=fed.k0, interpret=fed.kernel_interpret,
                donate=donate_kernel,
            )
        else:
            xa, pia, za = self._admm_branch_flat(state, xbar_c, gbar, spec)
            pig = gbar * -1.0  # eq. (16)
            zg = (-1.0 / sigma) * gbar + xbar_c  # eq. (17)
            pi_new = api.masked_update(sel, pia, pig)
            z_new = api.masked_update(sel, za, zg)

        new_state = dict(state)
        new_state.update(
            x=xbar, z=z_new, pi=pi_new, rng=rng, round=state["round"] + 1
        )
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fed.h_policy == "diag_ema":
            new_state["h"] = hparams.update_diag_h(state["h"], gbar,
                                                   state["r"], m)

        if ovl is not None:
            # upload half of the split collective: reduce-scatter the
            # FRESH z (next round's eq. (11) numerator) before handing the
            # round back — the next round's top only all-gathers. The
            # codec key is round_key(rng, round+1): exactly the key the
            # barrier round t+1 would draw, so the compressed stream is
            # unchanged. The g²-norm / loss / selection metrics ride the
            # same collective as scalar psum lanes instead of issuing
            # their own (flat_grad_sq_norm would add a second
            # reduce-scatter).
            z_up_new = z_new
            if compressor is not None:
                ef = state.get("ef") if compressor.error_feedback else None
                z_up_new, ef_new = api.compress_upload(
                    compressor, z_up_new, ef, spec,
                    key=compress.round_key(rng, state["round"] + 1))
                new_state["ef"] = ef_new
            # faults/screening hit the upload where it happens — at the
            # round END. The draw is keyed round+1 (the barrier round
            # whose aggregation this upload feeds, matching the codec
            # key convention), and the screened mask rides the same
            # reduce-scatter's scalar lanes.
            sc_mask = None
            if hardened:
                z_up_new, sc_mask, fprev_new, n_scr = api.harden_upload(
                    z_up_new, None, spec, faults=faults,
                    screening=screening,
                    fault_prev=state.get("fault_prev"),
                    round_idx=state["round"] + 1)
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate(
                z_up_new, spec.ravel_stacked(grads), losses, sel, spec,
                mask=sc_mask, weights=api.stale_weights(stale))
            new_state["ovl_shard"] = slot
            metrics = {
                "f_xbar": f_mean,
                "grad_sq_norm": gsq,
                "selected": n_sel,
                "cr": 2.0 * (state["round"] + 1).astype(jnp.float32),
                "local_grad_evals": jnp.float32(1.0),
            }
        else:
            metrics = {
                "f_xbar": api.client_scalar_mean(losses),
                "grad_sq_norm": api.flat_grad_sq_norm(
                    spec.ravel_stacked(grads), spec),
                "selected": api.client_scalar_sum(sel),
                "cr": 2.0 * (state["round"] + 1).astype(jnp.float32),
                "local_grad_evals": jnp.float32(1.0),  # per client per round (C2)
            }
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ----------------------------------------------------------------- round
    def round(self, state, batch, mask=None, stale=None):
        """One communication round (Algorithm 1, steps (1)-(5)).

        `mask`: engine participation mask = the ADMM/GD branch split.
        `stale`: async stale-x̄ state (`api.StaleXbar`). When given (mask
        required), each client's gradient and branch anchor is its own
        possibly stale view x̄^(t-s) instead of the fresh x̄ᵗ — the
        inexact-ADMM analysis tolerates the bounded perturbation (see
        docs/async.md). The server-side state update is untouched and
        eq. (11) stays the round's one psum; with a non-uniform
        `stale.weighting` the aggregation downweights each z_i by the age
        of the anchor it was computed against (`api.stale_weights` — the
        incoming `last_used`, i.e. the staleness of the round that
        PRODUCED the current z_i), riding the same psum.
        """
        fed = self.fed
        m = fed.num_clients
        m_local = api.local_client_count(m)
        sdt = jnp.dtype(fed.state_dtype)
        sigma = state["sigma"]
        assert stale is None or mask is not None, (
            "stale-x̄ rounds need the engine arrival mask"
        )

        # (1) aggregation — the round's ONLY model-size communication
        # (under client sharding this is the single psum of the round).
        # Staleness-aware weights (None = uniform = bitwise today's path)
        # downweight z_i computed against old anchors.
        xbar = api.client_mean(state["z"], weights=api.stale_weights(stale))  # eq. (11)

        # (3) client selection. The engine-drawn participation mask (when
        # given) decides the branch split and arrives pre-sliced to this
        # shard's clients; otherwise the in-algorithm §V.B draw derives the
        # full mask from the (replicated) round rng and each shard keeps
        # its own block. The rng splits either way, so the state's rng
        # stream is identical with and without an engine policy.
        rng, sel_key = jax.random.split(state["rng"])
        if mask is None:
            sel = api.local_client_slice(
                selection.selection_mask(
                    jax.random.fold_in(sel_key, state["round"]), m, fed.alpha
                )
            )
        else:
            sel = mask

        # (2) per-client gradient, once per round. Synchronous (and
        # statically-fresh async) rounds evaluate at the shared x̄; stale
        # rounds evaluate at each client's own anchor view.
        cast = (
            (lambda t: pt.tree_cast(t, self.model.dtype))
            if self.model is not None and hasattr(self.model, "dtype")
            else (lambda t: t)
        )
        if stale is None or stale.always_fresh:
            if stale is not None:
                xbar_c, stale = api.stale_xbar_view(stale, xbar, sel)
            else:
                xbar_c = broadcast_clients(xbar, m_local)
            losses, grads = self._vg(cast(xbar), batch)
        else:
            xbar_c, stale = api.stale_xbar_view(stale, xbar, sel)
            losses, grads = self._vg_per_anchor(cast(xbar_c), batch)
        gbar = pt.tree_cast(pt.tree_scale(grads, 1.0 / m), sdt)  # ḡ_i

        # (4) both branches, masked combine
        xa, pia, za = self._admm_branch(state, xbar_c, gbar)
        pig = pt.tree_scale(gbar, -1.0)  # eq. (16)
        zg = pt.tree_axpy(-1.0 / sigma, gbar, xbar_c)  # eq. (17)

        pi_new = api.masked_update(sel, pia, pig)
        z_new = api.masked_update(sel, za, zg)

        new_state = dict(state)
        new_state.update(
            x=xbar, z=z_new, pi=pi_new, rng=rng, round=state["round"] + 1
        )
        if fed.h_policy == "diag_ema":
            new_state["h"] = hparams.update_diag_h(state["h"], gbar, state["r"], m)

        gmean = api.client_mean(grads)
        metrics = {
            "f_xbar": api.client_scalar_mean(losses),
            "grad_sq_norm": pt.tree_sq_norm(gmean),
            "selected": api.client_scalar_sum(sel),
            "cr": 2.0 * (state["round"] + 1).astype(jnp.float32),
            "local_grad_evals": jnp.float32(1.0),  # per client per round (C2)
        }
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ------------------------------------------------------------ diagnostics
    def client_params(self, state):
        """x_i = z_i − π_i/σ (derived; never stored — DESIGN §6 B3)."""
        return pt.tree_axpy(-1.0 / state["sigma"], state["pi"], state["z"])

    def lagrangian(self, state, batch):
        """L(Z^k) of eq. (7) at a round boundary k = t*k0 — the monotone
        quantity of Lemma IV.1. At k in K the anchor is x^{tau_k} =
        mean(z^k) (the aggregation happens FIRST; the lemma's e1 term
        accounts for its decrease), so we evaluate at mean(z), not at the
        previous round's anchor."""
        m = self.fed.num_clients
        sigma = state["sigma"]
        xc = self.client_params(state)
        losses, _ = self._vg_values(xc, batch)
        xbar_c = broadcast_clients(pt.tree_mean_over_axis(state["z"], axis=0), m)
        diff = pt.tree_sub(xc, xbar_c)
        inner = pt.tree_dot(diff, state["pi"])
        quad = 0.5 * sigma * pt.tree_sq_norm(diff)
        return jnp.sum(losses) / m + inner + quad

    def _vg_values(self, xc_stacked, batch):
        loss = jax.vmap(lambda p, b: self.loss_fn(p, b)[0])(xc_stacked, batch)
        return loss, None

    # ----------------------------------------------------- active-set round
    def round_flat_active(self, state, batch, spec, active, stale=None,
                          compressor=None, donate_kernel=False,
                          faults=None, screening=None):
        """Active-store round (``run_rounds(store="active")``).

        FedGiA cannot shrink the round's working set: the GD branch
        (eqs. 15-17) recomputes EVERY non-selected client's (z, pi, h)
        from its fresh local gradient each round, so every client is
        read AND written regardless of the §V.B draw — `active_tile =
        "population"`. Packing m rows into an m-row tile is a pure
        permutation with no memory or compute win, so this delegates to
        the dense masked round (bitwise identical by construction). The
        active store's million-client payoff applies to the frozen-client
        family (FedAvg/FedProx/FedPD/SCAFFOLD), where non-participants
        are genuinely untouched. The same population argument routes the
        codec through the dense upload path (all m rows)."""
        return self.round_flat(state, batch, spec, active.mask, stale,
                               compressor=compressor,
                               donate_kernel=donate_kernel,
                               faults=faults, screening=screening)

    # --------------------------------------------------------------- overlap
    def overlap_finalize(self, state, slot):
        """Engine hook closing an overlapped run: FedGiA already stores the
        FRESH consensus in `state["x"]` every round (x does not lag — the
        carry slot holds the NEXT round's un-gathered numerator), so the
        pending shard is simply dropped."""
        return state
