"""Wall-clock simulation: per-client compute/communication time models.

The async engine (docs/async.md) counts staleness in ROUNDS, but the
paper's cost accounting (§V, Table 4) is about TIME: a client that is one
round late because its device is slow is not the same as one round late on
a fast device. A `ComputeClock` closes that gap by simulating each
client's wall-clock — how long one unit of local work (download + compute
+ upload) takes — and deriving the engine's per-round ARRIVAL MASK from
the simulated finish times instead of sampling it from a
`ParticipationPolicy` trace.

Event-driven semantics (`run_rounds(clock=...)`, which implies
`async_rounds=True`):

  * every client holds an in-flight work item finishing at simulated time
    ``busy_until[i]``; the clock state rides in the engine's scan carry
    exactly like a participation-policy state.
  * the server is event-driven: each round it advances its simulated time
    to the EARLIEST client finish, ``now' = max(now, min_i busy_until)``,
    so at least one client arrives every round (the engine's >= 1
    participant invariant holds by construction).
  * the round's arrival mask is ``busy_until <= now'`` — whoever has
    finished by the time the server wakes up uploads this round. Arrivals
    then download the fresh x̄ and start a new work item:
    ``busy_until[i] = now' + d_i`` with ``d_i`` drawn from the model.
  * the engine reports ``now'`` as the per-round ``sim_time`` history —
    time-to-target-accuracy is ``sim_time`` at the stopping round
    (benchmarks/wallclock_bench.py).

Initial state is ``busy_until = now = 0``: round 0 syncs everyone, which
matches the async engine's round-0 force-sync.

Two degenerate identities pin the model (tests/test_wallclock.py):

  * equal constant speeds ⇒ every client arrives every round ⇒ bitwise
    identical to the async engine under a full-participation arrival
    process;
  * constant integer speeds with a unit-speed client present ⇒ the mask
    sequence equals `AvailabilityParticipation.from_periods` with the
    speeds as periods — the clock GENERALISES the periodic trace policy
    (which is why the arrival process is now clock-backed end to end).
"""
from __future__ import annotations

import copy
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# (mask, sim_time_now, advanced clock state) — what `tick` returns
TickResult = Tuple[jax.Array, jax.Array, Any]


def _per_client(x, m: int, name: str) -> jax.Array:
    """Broadcast a scalar or validate an (m,) array of per-client seconds."""
    arr = jnp.asarray(x, jnp.float32)
    if arr.ndim == 0:
        arr = jnp.full((m,), arr)
    if arr.shape != (m,):
        raise ValueError(
            f"{name} must be scalar or (m={m},), got {arr.shape}"
        )
    return arr


class ComputeClock:
    """Base clock: CONSTANT per-client durations (compute_s + comm_s).

    ``compute_s`` / ``comm_s`` are per-client seconds for one unit of
    local work and one upload+download; a work item's duration is their
    sum. Durations must be strictly positive (a zero-duration client
    would arrive every round without ever advancing simulated time).

    ``bandwidth_bps`` (scalar or per-client bytes/second) switches the
    communication term to BYTE-ACCURATE accounting: the engine installs
    the round's exact per-client wire size (the compressor's
    ``wire_bytes`` + the fp32 downlink, core/compress.py) via
    :meth:`with_wire`, and each work item pays
    ``comm_s + (bytes_up + bytes_down) / bandwidth_bps`` of
    communication on top of its compute. ``bandwidth_bps=None``
    (default) keeps the constant-``comm_s`` model BITWISE — the
    byte-time term is never materialised, so every PR-4/5 ``sim_time``
    sequence is unchanged (tests/test_compress.py pins this against the
    committed BENCH_wallclock baseline).

    :meth:`with_overlap` (installed by the engine under
    ``run_rounds(overlap="scatter")``) switches the work-item duration
    from the sequential ``compute + comm`` to ``max(compute, comm)``:
    the split collective issues the upload's reduce-scatter at the round
    end and defers the consensus all-gather to the next round's top, so
    the wire hides behind local compute — crediting exactly
    ``min(compute, comm)`` per work item against the barrier clock.
    """

    name = "constant"

    def __init__(self, m: int, compute_s=1.0, comm_s=0.0,
                 bandwidth_bps=None, deadline_s=None):
        if m < 1:
            raise ValueError("need at least one client")
        if deadline_s is not None and not float(deadline_s) > 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        self.deadline_s = None if deadline_s is None else float(deadline_s)
        self.m = m
        self.compute_s = _per_client(compute_s, m, "compute_s")
        self.comm_s = _per_client(comm_s, m, "comm_s")
        total = np.asarray(self.compute_s) + np.asarray(self.comm_s)
        if not (total > 0).all():
            raise ValueError(f"work-item durations must be > 0, got {total}")
        if bandwidth_bps is None:
            self.bandwidth_bps = None
        else:
            self.bandwidth_bps = _per_client(bandwidth_bps, m,
                                             "bandwidth_bps")
            if not (np.asarray(self.bandwidth_bps) > 0).all():
                raise ValueError(
                    f"bandwidth_bps must be > 0, got {bandwidth_bps}")
        self.bytes_up = 0
        self.bytes_down = 0
        self.overlap = False
        self._recompute_durations()

    def _combine(self, compute):
        """Work-item duration from its compute time. Barrier rounds pay
        compute and communication sequentially — with the fp association
        ``(compute + comm_s) + wire_s`` kept EXACTLY as before overlap
        existed, so every non-overlapped ``sim_time`` sequence stays
        bitwise. Overlapped rounds hide the wire behind compute:
        ``max(compute, comm)``."""
        if not self.overlap:
            d = compute + self.comm_s
            if self.wire_s is not None:
                d = d + self.wire_s
            return d
        comm = (self.comm_s if self.wire_s is None
                else self.comm_s + self.wire_s)
        return jnp.maximum(compute, comm)

    def _recompute_durations(self):
        if self.bandwidth_bps is None:
            # bitwise escape: no byte-time term is ever added
            self.wire_s = None
        else:
            self.wire_s = (
                jnp.float32(self.bytes_up + self.bytes_down)
                / self.bandwidth_bps
            )
        self.durations_s = self._combine(self.compute_s)

    def with_wire(self, bytes_up: int, bytes_down: int) -> "ComputeClock":
        """A copy of this clock whose work items pay the byte time of
        ``bytes_up`` + ``bytes_down`` per round at ``bandwidth_bps``.
        The engine calls this once per `run_rounds` with the
        compressor's exact per-client wire size; the caller's clock
        object is never mutated (it can be reused across runs with
        different codecs)."""
        if self.bandwidth_bps is None:
            raise ValueError(
                "with_wire needs bandwidth_bps — construct the clock "
                "with bandwidth_bps= to enable byte-accurate comm time")
        clone = copy.copy(self)
        clone.bytes_up = int(bytes_up)
        clone.bytes_down = int(bytes_down)
        clone._recompute_durations()
        return clone

    def with_overlap(self) -> "ComputeClock":
        """A copy of this clock pricing overlapped rounds (the engine
        installs it under ``run_rounds(overlap="scatter")``): each work
        item pays ``max(compute, comm)`` instead of ``compute + comm`` —
        the communication hides behind the local compute scheduled
        between the split collective's two halves. Composes with
        :meth:`with_wire` (the byte-accurate wire folds into the comm
        term before the max)."""
        clone = copy.copy(self)
        clone.overlap = True
        clone._recompute_durations()
        return clone

    def init(self) -> Dict[str, Any]:
        """Clock carry state: in-flight finish times + the server's simulated
        time. ``busy_until = now = 0`` makes round 0 sync every client."""
        return {
            "busy_until": jnp.zeros((self.m,), jnp.float32),
            "now": jnp.zeros((), jnp.float32),
        }

    def _draw(self, cstate, round_idx):
        """Durations of work STARTED this round + any advanced sampler state.
        Pure and traceable (called inside the engine's compiled scan)."""
        return self.durations_s, cstate

    def tick(self, cstate, round_idx) -> TickResult:
        """One server event: advance simulated time to the earliest client
        finish, derive the arrival mask, restart arrived clients.

        Returns ``(mask, now, cstate')`` — the (m,) bool arrival mask (at
        least one True), the simulated time at which this round happens,
        and the advanced clock state. Pure and traceable; the engine calls
        it from the scan carry exactly like ``ParticipationPolicy.mask``,
        so clock-driven scan == clock-driven legacy holds the same way.
        """
        busy = cstate["busy_until"]
        if self.deadline_s is None:
            # event-driven: wake at the earliest finish, so >= 1 client
            # arrives every round by construction
            now = jnp.maximum(cstate["now"], jnp.min(busy))
        else:
            # deadline-driven: the server cuts the round a fixed
            # `deadline_s` after the previous one, whatever has finished.
            # Late clients are NOT waited for — they keep their in-flight
            # item and arrive at a later round once busy <= now (a round
            # may see ZERO arrivals; the engine's quorum degradation
            # absorbs it as a recorded no-op, which is why run_rounds
            # requires quorum >= 1 under a deadline clock).
            now = cstate["now"] + jnp.float32(self.deadline_s)
        mask = busy <= now
        d, cstate = self._draw(cstate, round_idx)
        cs2 = dict(cstate)
        cs2.update(busy_until=jnp.where(mask, now + d, busy), now=now)
        return mask, now, cs2


class LognormalClock(ComputeClock):
    """Lognormal compute-time jitter: each work item's compute time is
    ``compute_s[i] * exp(sigma * N(0, 1))`` (median = ``compute_s``),
    communication time stays constant. The PRNG key rides in the clock
    state, so the duration sequence is a pure function of ``seed`` —
    identical across the scan and legacy engine paths."""

    name = "lognormal"

    def __init__(self, m: int, compute_s=1.0, comm_s=0.0, sigma: float = 0.5,
                 seed: int = 0, bandwidth_bps=None, deadline_s=None):
        super().__init__(m, compute_s, comm_s, bandwidth_bps,
                         deadline_s=deadline_s)
        if sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {sigma}")
        self.sigma = float(sigma)
        self.seed = seed

    def init(self):
        cs = super().init()
        cs["key"] = jax.random.PRNGKey(self.seed)
        return cs

    def _draw(self, cstate, round_idx):
        key, sub = jax.random.split(cstate["key"])
        jitter = jnp.exp(self.sigma * jax.random.normal(sub, (self.m,)))
        cs2 = dict(cstate)
        cs2["key"] = key
        return self._combine(self.compute_s * jitter), cs2


class TraceClock(ComputeClock):
    """Trace-driven durations: a (T, m) table of measured per-work-item
    seconds; work started at round t uses row ``t mod T`` (replayed
    modulo the trace length). Use for profiles captured from a real
    heterogeneous fleet."""

    name = "trace"

    def __init__(self, m: int, trace, bandwidth_bps=None, deadline_s=None):
        tr = np.asarray(trace, np.float32)
        if tr.ndim != 2 or tr.shape[1] != m:
            raise ValueError(f"trace must be (T, m={m}), got {tr.shape}")
        if not (tr > 0).all():
            raise ValueError("trace durations must be > 0")
        super().__init__(m, compute_s=tr[0], comm_s=0.0,
                         bandwidth_bps=bandwidth_bps, deadline_s=deadline_s)
        self.trace = jnp.asarray(tr)

    def _draw(self, cstate, round_idx):
        t = jnp.asarray(round_idx, jnp.int32) % self.trace.shape[0]
        return self._combine(jnp.take(self.trace, t, axis=0)), cstate


CLOCKS = ("constant", "lognormal", "trace")


def default_speeds(m: int) -> np.ndarray:
    """Heterogeneous default: per-client compute seconds cycling 1..4 —
    the wall-clock twin of `selection.make_policy("periodic")`'s default
    periods, so the two arrival processes are comparable out of the box."""
    return 1.0 + (np.arange(m) % 4).astype(np.float32)


def make_clock(
    kind: str,
    m: int,
    *,
    compute_s=None,
    comm_s=0.0,
    sigma: float = 0.5,
    seed: int = 0,
    trace=None,
    bandwidth_bps=None,
    deadline_s=None,
) -> Optional[ComputeClock]:
    """CLI-level factory (launch: --clock/--client-speeds). ``kind="none"``
    returns None — rounds stay trace- or policy-driven. ``compute_s``
    defaults to `default_speeds` (per-client seconds cycling 1..4).
    ``bandwidth_bps`` enables byte-accurate comm time (the engine feeds
    the codec's exact wire size per round; None keeps the constant
    ``comm_s`` model bitwise). ``deadline_s`` switches the server from
    event-driven (wake at the earliest finish) to deadline-driven rounds:
    the round is cut ``deadline_s`` simulated seconds after the previous
    one and whoever has finished by then uploads — stragglers re-arrive
    at a later round instead of blocking (None keeps the event-driven
    tick bitwise)."""
    if kind == "none":
        return None
    if compute_s is None:
        compute_s = default_speeds(m)
    if kind == "constant":
        return ComputeClock(m, compute_s, comm_s,
                            bandwidth_bps=bandwidth_bps,
                            deadline_s=deadline_s)
    if kind == "lognormal":
        return LognormalClock(m, compute_s, comm_s, sigma=sigma, seed=seed,
                              bandwidth_bps=bandwidth_bps,
                              deadline_s=deadline_s)
    if kind == "trace":
        if trace is None:
            raise ValueError("trace clock needs a (T, m) duration table")
        return TraceClock(m, trace, bandwidth_bps=bandwidth_bps,
                          deadline_s=deadline_s)
    raise KeyError(f"unknown clock {kind!r}: {CLOCKS} or 'none'")
