"""Scan-compiled, client-sharded round engine.

Every federated algorithm in this repo (FedGiA + the four §V.D baselines)
exposes the same `FederatedAlgorithm` protocol (core/api.py): a pure
`round(state, batch) -> (state, metrics)`. The legacy driver dispatched one
jitted round per Python iteration and synced a metric scalar to the host
every round — on small problems the wall-clock is dominated by dispatch,
not math. This engine removes both costs without changing a single number
(tests/test_engine.py asserts bitwise-faithful fp32 equivalence):

  * **scan path** — `run_rounds` compiles CHUNKS of rounds into a single
    `jax.lax.scan` inside one jit with the carry donated. Per-round metrics
    are stacked device-side; the tolerance check of the paper's stopping
    rule (eq. 35) runs INSIDE the scan: a `lax.cond` freezes the carry once
    the tolerance is met, so finished rounds cost (almost) nothing and the
    host syncs ONE boolean per chunk instead of one float per round.
  * **client-sharded path** — `mesh=` places the leading client axis of the
    client state (`algo.client_state_keys`) and the batch over a mesh axis
    with `shard_map`. Cross-client reductions inside `round` go through
    `api.client_mean` & friends, so eq. (11)'s aggregation lowers to the
    round's ONE `psum` — exactly the paper's single all-reduce per round.
  * **legacy path** — `scan=False` keeps the per-round Python loop
    (`--no-scan` in the launchers) for debugging.
  * **partial participation** — `participation=` takes a
    `core.selection.ParticipationPolicy`; its state rides in the scan
    carry, a fresh (m,) mask is drawn on device every round and handed to
    `round(state, batch, mask)` (auto-sliced per shard on the sharded
    path, where the masked aggregation still lowers to ONE psum). See
    docs/engine.md.
  * **async / overlapped rounds** — `async_rounds=True` reinterprets the
    participation mask as an ARRIVAL process: a `StaleXbar` buffer
    (core/api.py) rides in the scan carry next to the policy state, and a
    client that has not arrived for s rounds runs its branch against the
    stale anchor x̄^(t-s), s <= `max_staleness` (bounded by a forced
    server sync). `max_staleness=0` is bitwise identical to the masked
    synchronous engine on every path. See docs/async.md.
  * **wall-clock rounds** — `clock=` takes a `core.clock.ComputeClock`
    (per-client compute/communication time model) and makes the arrival
    mask EVENT-DRIVEN: the clock's state (in-flight finish times +
    simulated server time) rides in the scan carry and each round's mask
    is derived from simulated client finish times instead of sampled
    from a policy. Rounds report the simulated wall-clock (`sim_time`)
    alongside CR, and `stale_weighting=` turns eq. (11) into the
    staleness-aware weighted mean (`api.stale_weights`) — uniform
    weighting is today's unweighted path, bitwise. See docs/async.md.

  * **flat-buffer rounds** — `flat=True` (default) ravels the model-shaped
    state ONCE at the `run_rounds` boundary (`utils.pytree.ravel_spec`):
    client state becomes one contiguous lane-padded (m, N) buffer per key,
    anchors (N,) vectors, and the rounds dispatch to `algo.round_flat`.
    Eq. (11) is a mean over a single array (under sharding: the round's
    ONE model-size all-reduce), the stale anchor buffer is one (m, N)
    array, and FedGiA's ADMM/GD branch is one fused elementwise pass
    (the batched Pallas `kernels/fedgia_update` kernel on TPU). The
    pytree layout is reconstructed only at the gradient/metric
    boundaries and at return; `flat=False` (`--no-flat`) keeps the
    per-leaf pytree rounds, bitwise-equal on a single device
    (tests/test_flat.py). See docs/engine.md#flat-buffer-round-state.

  * **overlapped collectives** — `overlap="scatter"` splits eq. (11)'s
    psum into a round-END `psum_scatter` into a column-sharded carry slot
    (`state["ovl_shard"]`) plus a round-TOP `all_gather` of the consensus
    back out, so the local compute between them hides the wire; the
    client axis may span pods (`client_axis=("pod", "data")`) and the
    Pallas hot path can donate its buffers (`donate_kernel=`). See
    docs/engine.md#overlapped-collectives.

Scan-carry layout (donated between chunks):

    (state, policy_state, clock_state, stale, done, rounds_run)

where `state` is the algorithm state dict, `policy_state` the
participation policy's pytree (() when participation is None),
`clock_state` the wall-clock simulation state (() when clock is None),
`stale` the async `StaleXbar` (() when async_rounds is False), `done`
the eq.-35 stop flag and `rounds_run` an int32 round counter. The legacy
loop threads the same tuple through its per-round jitted step, which is
why scan == legacy holds exactly for every feature combination.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt_io
from repro.core import api, compress
from repro.utils import pytree as pt


@dataclasses.dataclass
class RoundResult:
    """Outcome of `run_rounds`: final state + stacked per-round metrics."""

    state: Any
    history: Dict[str, np.ndarray]  # each (rounds_run,), trimmed at early stop
    rounds_run: int
    stopped_early: bool
    wall_s: float
    # Path-specific diagnostics that are not per-round metrics. The
    # host-offloaded store reports `device_peak_bytes` (XLA
    # memory_analysis of the compiled tile round, when the backend
    # exposes it) and `host_resident_bytes` (the buffers that left the
    # device). Empty for the dense/active paths.
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------- sharding
def _full_spec(leading: Optional[str], ndim: int) -> P:
    return P(leading, *([None] * (ndim - 1))) if ndim else P()


def _state_specs(algo, state_like, axis):
    """Per-leaf PartitionSpecs: client-stacked top-level keys on `axis`
    (`axis` may be a compound tuple, e.g. ``('pod', 'data')``). The
    overlap carry slot ``"ovl_shard"`` is the one exception: it holds the
    reduce-scattered consensus CHUNKS, sharded over COLUMNS, not over a
    leading client axis — spec ``P(None, axis)``."""
    client_keys = set(getattr(algo, "client_state_keys", ()))
    specs = {
        k: jax.tree.map(
            lambda l, kk=k: _full_spec(axis if kk in client_keys else None, l.ndim),
            v,
        )
        for k, v in state_like.items()
    }
    if "ovl_shard" in specs:
        specs["ovl_shard"] = P(None, axis)
    return specs


def _batch_specs(batch_like, axis):
    return jax.tree.map(lambda l: _full_spec(axis, l.ndim), batch_like)


def _client_axes(client_axis) -> tuple:
    """Normalise `client_axis` to a tuple of mesh axis names: the client
    dimension may span one axis (``"data"``) or a compound of several
    (``("pod", "data")`` — pod-spanning client sharding)."""
    return client_axis if isinstance(client_axis, tuple) else (client_axis,)


def _client_shards(mesh, client_axis) -> int:
    """Total client shards = product of the client axes' mesh sizes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shards = 1
    for a in _client_axes(client_axis):
        if a not in sizes:
            raise ValueError(f"mesh has no axis {a!r}: {mesh.axis_names}")
        shards *= sizes[a]
    return shards


def flatten_state(algo, state, spec):
    """Ravel the algorithm state's model-shaped entries into flat buffers:
    `algo.flat_global_keys` -> (N,) vectors, `algo.flat_client_keys` ->
    one (m, N) buffer each (`spec` = `pt.ravel_spec(state["x"])`). Done
    ONCE at the `run_rounds` boundary; everything else (rng, scalars,
    gram factors) passes through untouched."""
    out = dict(state)
    for k in getattr(algo, "flat_global_keys", ()):
        if k in out:
            out[k] = spec.ravel(out[k])
    for k in getattr(algo, "flat_client_keys", ()):
        if k in out:
            out[k] = spec.ravel_stacked(out[k])
    return out


def unflatten_state(algo, state, spec):
    """Inverse of `flatten_state` — the return boundary: callers always
    see the pytree state layout, whichever path ran the rounds."""
    out = dict(state)
    for k in getattr(algo, "flat_global_keys", ()):
        if k in out:
            out[k] = spec.unravel(out[k])
    for k in getattr(algo, "flat_client_keys", ()):
        if k in out:
            out[k] = spec.unravel_stacked(out[k])
    return out


def make_round_fn(algo, mesh=None, client_axis="data",
                  masked: bool = False, stale: bool = False,
                  flat_spec=None, active_capacity: Optional[int] = None,
                  compressor=None, overlap: str = "off",
                  donate_kernel: bool = False, aggregate: str = "dense",
                  faults=None, screening=None):
    """`algo.round`, optionally wrapped in `shard_map` over the client axis.

    `masked=True` returns a `(state, batch, mask) -> (state, metrics)`
    callable: the engine-drawn (m,) participation mask enters `shard_map`
    with spec `P(client_axis)`, so each shard's round body receives its
    own contiguous (m_local,) block — algorithms never re-slice it.

    `stale=True` (implies masked) additionally threads the async
    `StaleXbar` state: the callable is `(state, batch, mask, stale) ->
    (state, stale, metrics)`. Every StaleXbar leaf carries the leading
    client axis, so it enters and leaves `shard_map` with per-client
    specs — the stale-anchor selects are shard-local and the round keeps
    eq. (11) as its ONE model-size psum.

    `flat_spec` (a `pt.RavelSpec`) selects the FLAT round: the callable
    has the same signature but `state` carries the raveled (m, N) /
    (N,) buffers (`flatten_state`) and dispatch goes to
    `algo.round_flat(state, batch, spec, ...)` instead of `algo.round`.

    `active_capacity` (with `flat_spec`, implies masked) selects the
    ACTIVE-SET round (`run_rounds(store="active")`): the round's (m,)
    mask is packed into a `pt.ActiveSet` of that static capacity INSIDE
    the round body and dispatch goes to `algo.round_flat_active`. The
    callable's signature is unchanged — the pack happens downstream of
    the mask draw, so the scan carry, the chunked drivers and the legacy
    loop are identical between stores. Under a mesh the pack runs inside
    `shard_map` on the shard-local (m_local,) mask, so the capacity is
    clamped to m_local (a shard can never host more participants than it
    has clients).

    `compressor` (a `core.compress.Compressor`, flat rounds only) is
    threaded into `round_flat`/`round_flat_active` as a keyword: each
    client's eq.-(11) contribution is encoded+decoded LOCALLY before it
    enters the round's aggregation (decompress-before-reduce), so the
    sharded round still lowers to its ONE model-size all-reduce. None
    keeps the uncompressed round — structurally, not just numerically.

    `client_axis` may be a single mesh axis name or a compound tuple
    (``("pod", "data")``): client state and batch shard over the product
    of the named axes and every cross-client collective runs over the
    compound axis — pod-spanning client sharding with no change to the
    round bodies.

    `overlap="scatter"` (flat rounds only) validates the split-collective
    round here: the round body reads the previous round's consensus from
    the ``state["ovl_shard"]`` carry slot (`api.flat_overlap_consensus`'s
    all-gather at the round TOP) and writes this round's reduction back
    with `api.flat_overlap_aggregate`'s reduce-scatter at the round END —
    `run_rounds` creates/finalises the slot. Under a mesh the lane-padded
    buffer must divide over the client shards (the reduce-scatter chunks
    columns). ``"off"`` keeps the one-psum barrier round, bitwise.

    `donate_kernel=True` threads Pallas buffer donation into the flat
    rounds (`FedGiA.round_flat(donate_kernel=True)`): the kernel aliases
    its (m, N) state inputs to its outputs (`input_output_aliases`), so
    the hot-path update is in-place end-to-end under the donated scan
    carry. Ignored by algorithms without a kernel path.

    `faults` (a `core.faults.FaultModel`) / `screening`
    (`core.faults.Screening`) thread the fault-injection and defensive
    screening stage into the flat rounds (`api.harden_upload[_active]`
    between the codec and the aggregation): faults corrupt the decoded
    uploads on device from a stateless per-(round, client) key stream —
    identical across scan/legacy, stores and shardings — and screening
    folds a per-row finite check + norm clip into the participation mask
    BEFORE eq. (11)'s psum, so the sharded round keeps its one
    model-size collective set. None/None keeps the un-hardened round —
    structurally, not just numerically.

    `aggregate="packed"` (active rounds only) opts eq. (11) into the
    fp-tolerance packed aggregation: the unsharded round sums the
    (capacity, N) tile directly instead of scattering it back to the
    dense (m, N) layout first (`ActiveSet.packed`; ~1 ulp from the
    bitwise dense default). Under a mesh the flag is a no-op — the
    sharded branch already keeps packed O(capacity) sums inside the
    round's one psum, so the lowered program is unchanged.
    """
    if overlap not in ("off", "scatter"):
        raise ValueError(f"unknown overlap {overlap!r}: ('off', 'scatter')")
    if overlap == "scatter" and flat_spec is None:
        raise ValueError(
            "overlap='scatter' splits the flat comm buffer's collective — "
            "it requires the flat round path (flat=True on an algorithm "
            "providing round_flat; drop --no-flat)")
    if aggregate not in ("dense", "packed"):
        raise ValueError(
            f"unknown aggregate {aggregate!r}: ('dense', 'packed')")
    if aggregate == "packed" and active_capacity is None:
        raise ValueError(
            "aggregate='packed' sums the packed participant tile — it "
            "requires the active-set round (store='active' or 'offload')")
    if flat_spec is not None and active_capacity is not None:
        cap = active_capacity
        if mesh is not None:
            cap = min(cap,
                      algo.fed.num_clients // _client_shards(mesh, client_axis))
        packed = aggregate == "packed"

        def base_round(state, batch, mask, *extra):
            aset = pt.make_active_set(mask, cap, packed=packed)
            return algo.round_flat_active(state, batch, flat_spec, aset,
                                          *extra, compressor=compressor,
                                          donate_kernel=donate_kernel,
                                          faults=faults, screening=screening)
    elif flat_spec is not None:
        base_round = lambda state, batch, *extra: algo.round_flat(
            state, batch, flat_spec, *extra, compressor=compressor,
            donate_kernel=donate_kernel, faults=faults, screening=screening)
    else:
        if compressor is not None:
            raise ValueError(
                "compression operates on the flat (m, N) comm buffer — "
                "the pytree round path (flat=False) does not support it")
        if faults is not None or screening is not None:
            raise ValueError(
                "faults/screening operate on the flat (m, N) comm buffer — "
                "the pytree round path (flat=False) does not support them")
        base_round = algo.round
    if mesh is None:
        if stale:
            return lambda state, batch, mask, sl: base_round(
                state, batch, mask, sl)
        if masked:
            return lambda state, batch, mask: base_round(state, batch, mask)
        return base_round
    shards = _client_shards(mesh, client_axis)
    m = algo.fed.num_clients
    if m % shards != 0:
        raise ValueError(f"num_clients={m} not divisible by {shards} shards")
    if overlap == "scatter" and flat_spec.padded_size % shards != 0:
        raise ValueError(
            f"overlap='scatter' reduce-scatters the lane-padded buffer "
            f"column-wise: padded_size={flat_spec.padded_size} must divide "
            f"over {shards} client shards")

    client_spec = lambda tree: jax.tree.map(
        lambda l: _full_spec(client_axis, l.ndim), tree
    )

    def body(state, batch, *extra):
        # context makes api.client_mean/... collective over `client_axis`
        with api.client_sharding(client_axis, shards):
            return base_round(state, batch, *extra)

    def sharded_round(state, batch, *extra):
        abs_out = jax.eval_shape(base_round, state, batch, *extra)
        in_specs = (_state_specs(algo, state, client_axis),
                    _batch_specs(batch, client_axis))
        if masked or stale:
            in_specs = in_specs + (P(client_axis),)  # the (m,) mask
        if stale:
            in_specs = in_specs + (client_spec(extra[1]),)
            abs_state, abs_stale, abs_met = abs_out
            out_specs = (_state_specs(algo, abs_state, client_axis),
                         client_spec(abs_stale),
                         jax.tree.map(lambda l: _full_spec(None, l.ndim),
                                      abs_met))
        else:
            abs_state, abs_met = abs_out
            out_specs = (_state_specs(algo, abs_state, client_axis),
                         jax.tree.map(lambda l: _full_spec(None, l.ndim),
                                      abs_met))
        return shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )(state, batch, *extra)

    return sharded_round


def shard_inputs(algo, state, batch, mesh, client_axis: str = "data"):
    """Place client-stacked leaves over `client_axis`, replicate the rest."""
    sspec = _state_specs(algo, state, client_axis)
    bspec = _batch_specs(batch, client_axis)
    put = lambda tree, spec: jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, spec
    )
    return (
        {k: put(v, sspec[k]) for k, v in state.items()},
        put(batch, bspec),
    )


# ------------------------------------------------------------------ driver
AUTO_CHUNK_CANDIDATES = (8, 32, 128)


def run_rounds(
    algo,
    state,
    batch,
    num_rounds: int,
    *,
    tol: float = 0.0,
    tol_metric: str = "grad_sq_norm",
    scan: bool = True,
    chunk_size=0,
    donate: Optional[bool] = None,
    mesh=None,
    client_axis: str = "data",
    participation=None,
    async_rounds: bool = False,
    max_staleness: int = 0,
    clock=None,
    stale_weighting: str = "uniform",
    stale_decay: float = 1.0,
    flat: bool = True,
    store: str = "dense",
    aggregate: str = "dense",
    compression=None,
    error_feedback: bool = False,
    topk_frac: float = 0.1,
    overlap: str = "off",
    donate_kernel: Optional[bool] = None,
    faults=None,
    screening=None,
    quorum: int = 0,
    watchdog: bool = False,
    watchdog_patience: int = 3,
    watchdog_factor: float = 2.0,
    checkpoint_every: int = 0,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
) -> RoundResult:
    """Run up to `num_rounds` communication rounds of `algo`.

    tol > 0 enables the paper's stopping rule (eq. 35): stop after the
    first round with metrics[tol_metric] < tol (that round counts as run).
    chunk_size=0 picks a default: the whole run when tol is off, else 32
    rounds between (single-boolean) host checks. chunk_size="auto"
    autotunes on the live run (unsharded scan path only — the sharded
    path has no AOT warm-up, so candidate timings would measure
    compilation): the first chunks execute the AOT-pre-compiled
    `AUTO_CHUNK_CANDIDATES` lengths in turn, each is timed, and the
    fastest per-round candidate drives the remainder. The rounds executed
    are identical whatever the timings, so with tol <= 0 results are
    bitwise deterministic; with tol > 0 only the stop GRANULARITY (which
    is already chunk-dependent) can differ between machines. The tuner
    composes with store="active": the tile gather/scatter runs inside
    every round whatever the chunk length, so candidate timings stay
    comparable and the winning chunk is store-independent
    (tests/test_store.py pins auto-chunk == fixed-chunk under the active
    store).

    flat=True (default) runs the FLAT round path when the algorithm
    provides it (`round_flat`): the model-shaped state is raveled ONCE
    into contiguous lane-padded buffers (`utils.pytree.ravel_spec`) —
    client state one (m, N) array, anchors (N,) — the scan/legacy/sharded
    drivers carry those buffers, and the pytree layout is reconstructed
    only at the gradient/metric boundaries inside the round and at this
    function's return. Eq. (11) becomes one contiguous model-size
    reduction (under sharding: the round's single model-size all-reduce,
    HLO-asserted in tests/test_flat.py) and FedGiA's branch update a
    single fused elementwise pass (the batched Pallas kernel on TPU).
    `flat=False` (`--no-flat` in the launchers) keeps the per-leaf pytree
    rounds; both paths produce bitwise-identical results on every
    single-device configuration (fp-tolerance where the Pallas kernel or
    the sharded fused psum is involved — tests/test_flat.py).

    participation: a `core.selection.ParticipationPolicy`. Its state rides
    in the scan carry and a fresh (m,) mask is drawn ON DEVICE each round
    and passed to `round(state, batch, mask)` (sliced per shard on the
    client-sharded path). None keeps the legacy in-algorithm behaviour.

    async_rounds: overlapped (stale-x̄) rounds. Requires an arrival
    process — either a participation policy (its mask becomes WHO
    uploads/downloads this round) or a `clock`. An `api.StaleXbar` buffer
    rides in the scan carry: each client anchors its branch on the x̄ it
    last downloaded, at most `max_staleness` rounds old (over-stale
    clients are force-synced first). The history gains a per-round
    `staleness` (m,) vector and `staleness_max` scalar.
    `max_staleness=0` is bitwise identical to the synchronous masked
    engine (tests/test_async.py pins this for all five algorithms).

    clock: a `core.clock.ComputeClock` — wall-clock event-driven rounds
    (implies async_rounds; mutually exclusive with `participation`). The
    clock's state rides in the scan carry and each round's arrival mask
    is DERIVED from simulated client finish times; the history gains the
    per-round simulated wall-clock `sim_time`. With identical client
    speeds every client arrives every round — bitwise identical to a
    full-participation arrival policy (tests/test_wallclock.py).

    stale_weighting/stale_decay: staleness-aware aggregation schedule for
    eq. (11) (`api.stale_weights`): "uniform" (default, today's
    unweighted path — bitwise), "poly" ((1+s)^-decay) or "exp"
    (e^(-decay*s)). Requires async_rounds (or clock).

    store: client-state execution strategy for the flat path. "dense"
    (default) keeps every round's working set (m, N) — trajectories and
    gradients are computed for all m clients and non-participants are
    masked out, the only shape-stable formulation when every client runs
    a branch (FedGiA's GD rewrite). "active" packs the round down to the
    participants: the resident (m, N) client buffers stay in the donated
    scan carry, but each round GATHERS a (capacity, N) tile of the
    selected clients (capacity = `participation.active_capacity`, or m
    under a clock), runs the algorithm's `round_flat_active` on the
    tile, and SCATTERS per-client state back — the round's broadcasts,
    trajectories and gradient evaluations shrink from m rows to
    capacity, which is what makes m=10^6, alpha=10^-4 rounds tractable
    (benchmarks/engine_bench.py `active_1m`). States are bitwise equal
    between stores (tests/test_store.py); loss/gradient diagnostics
    become PARTICIPANT means — the server cannot observe clients it
    never contacted. Requires flat=True and a participation policy or
    clock; FedGiA declares `active_tile="population"` (every client is
    rewritten every round by eqs. 15-17) and falls back to the dense
    round internally. "offload" moves the resident (m, N) client
    buffers (and the batch + StaleXbar anchor) into HOST memory
    (pinned host memory where the backend supports computing on it,
    else the CPU device — `pt.host_placement`): each round gathers only
    the (capacity, N) participant tiles to the device, runs
    `round_flat_active` in tile mode (`ActiveSet.tile_state`), and
    scatters the updated tiles back host-side — double-buffered (the
    next round's mask/batch tile are staged while the current round's
    device compute is in flight) with tile donation off-CPU, so m is
    bounded by host RAM instead of device HBM. Bitwise equal to
    store="active" (host gather/scatter is pure data movement —
    tests/test_store.py); single-device only (no mesh/overlap; the
    scan flag is accepted but the loop is host-driven, so
    chunk_size="auto" is rejected). FedGiA's population tile shuttles
    the full buffers each round instead (residency, not per-round
    traffic, is what moves off-device). `RoundResult.extras` reports
    `device_peak_bytes` / `host_resident_bytes`. See
    docs/engine.md#host-offloaded-store and docs/scaling.md.

    aggregate: eq. (11) aggregation layout for active/offload rounds.
    "dense" (default) scatters the participant tile back to the dense
    (m, N) layout before reducing — bitwise the dense store. "packed"
    sums the (capacity, N) tile directly — O(capacity·N) and no dense
    (m, N) aggregation temp, at fp tolerance (~1 ulp: XLA associates
    the two reduction shapes differently). Under a mesh the sharded
    branch is already packed inside its one psum, so the flag leaves
    the lowered program unchanged. See
    docs/engine.md#packed-aggregation.

    compression: uplink codec for the flat comm buffer — "none"/None,
    "bf16", "int8", "topk" or a `core.compress.Compressor` instance.
    Each client's eq.-(11) contribution is encoded+decoded LOCALLY
    before it enters the round's aggregation (decompress-before-reduce),
    so the sharded round keeps its ONE model-size all-reduce and `none`
    is BITWISE the uncompressed engine (the identity codec without
    error feedback resolves to the very same lowered program —
    tests/test_compress.py pins this for all five algorithms).
    Requires the flat round path.

    error_feedback: per-client error-feedback residuals (EF): each
    client uploads C(contrib + ef) and keeps ef' = (contrib + ef) -
    C(contrib + ef) in one extra (m, N) flat buffer `state["ef"]`
    riding the scan carry like any other flat client key (dense and
    active stores carry it for free; non-participants' residuals are
    frozen). Requires a lossy compression codec.

    topk_frac: fraction of lanes the "topk" codec keeps (largest-|·|
    per client), 0 < topk_frac <= 1.

    With a byte-accurate clock (`clock.bandwidth_bps` set) the codec's
    exact wire size prices the simulated communication time: the engine
    installs `compress.uplink_bytes`/`downlink_bytes` of the model on
    the clock (`ComputeClock.with_wire`) and the history gains per-round
    `bytes_up`/`bytes_down` totals (arrived clients × per-client wire).

    overlap: ``"off"`` (default) keeps the barrier round — eq. (11) as
    one fused model-size psum, bitwise the PR-5 program. ``"scatter"``
    splits it: each round ENDS with a `psum_scatter` of the stacked
    contribution rows (`api.flat_overlap_aggregate`) into a
    column-sharded carry slot ``state["ovl_shard"]``, and the NEXT round
    STARTS by all-gathering the consensus back
    (`api.flat_overlap_consensus`) — the local compute between the two
    halves hides the wire. The slot is a pure carry-layout change: its
    row 0 is exactly the mean the barrier round would have computed, so
    results are bitwise the barrier engine unsharded (fp tolerance under
    a mesh, where the reduce-scatter reassociates the sum) — the only
    semantic shift is FedGiA's uplink-compression timing (the z upload is
    encoded at round end instead of the next round's top; lossless runs
    are unaffected, see docs/engine.md#overlapped-collectives). At the
    return boundary the engine folds the slot back into the state
    (``algo.overlap_finalize`` when defined, else ``x = slot[0]``), so
    callers see the ordinary state layout. Requires the flat round path;
    with a clock, round durations become ``max(compute, comm)`` instead
    of ``compute + comm`` (`ComputeClock.with_overlap`). Under a mesh the
    lane-padded buffer must divide over the client shards.

    faults / screening: fault-tolerant rounds (docs/faults.md). `faults`
    (a `core.faults.FaultModel`) corrupts the decoded uploads ON DEVICE
    just before eq. (11) — crash/drop, NaN/Inf payloads, update
    explosions, stale replays — from a stateless per-(round, client) key
    stream, so the injected stream is identical across scan/legacy, all
    three stores and shardings, and across checkpoint resume (no fault
    rng rides the carry). `screening` (`core.faults.Screening`) is the
    defense: a per-row finite check + optional norm clip folded into the
    participation mask BEFORE the psum — the screened mask and clip
    scale are riders on the round's ONE model-size collective set
    (tests/test_faults.py HLO-asserts {1 AR} / {1 RS, 1 AG}). The
    history gains a per-round `screened` count. Flat rounds only.

    quorum: minimum accepted-upload count for a round to COMMIT. A round
    whose screened/selected count falls below it becomes a recorded
    no-op: every state entry except the rng and the round counter
    reverts (x̄ is carried, partial aggregation is never applied — the
    biased mean of eq. (11) over too few clients is worse than waiting),
    and the history records `degraded=True` for that round. quorum=0
    (default) keeps today's always-commit rounds structurally unchanged.
    Required >= 1 under a deadline clock (`ComputeClock(deadline_s=)`),
    whose rounds can see zero arrivals.

    watchdog: carry-resident divergence watchdog. Tracks the best f̄
    seen (`f_xbar`) plus a full state snapshot in the scan carry; after
    `watchdog_patience` consecutive non-degraded rounds with
    f̄ > `watchdog_factor` × best (NaN counts as diverged), the state
    rolls back to the snapshot (rng/round keep advancing — the run does
    not relive the same faults) and the history records
    `rollback=True`. Degraded rounds never advance the patience counter.
    The snapshot doubles the carry, so the watchdog is opt-in; with
    store="offload" it is rejected (it would double host residency).

    checkpoint_every / checkpoint_dir / resume: bitwise checkpoint +
    resume (docs/faults.md#checkpointing). Every `checkpoint_every`
    rounds the FULL carry — state (incl. ef / fault_prev / overlap
    slot), policy/clock state, StaleXbar, watchdog slot, rng, stop flag
    — plus the history so far is written through
    `checkpoint/checkpoint.py` (atomic npz). `resume=True` restores the
    newest checkpoint under `checkpoint_dir` (a fresh start when none
    exists) and the resumed run's history and final state are BITWISE
    the uninterrupted run's. Checkpoints embed a config fingerprint;
    resuming under a different round-semantics configuration raises
    (num_rounds is excluded — extending a finished run is the point).
    Supported on the chunked scan driver and the host-driven offload
    loop; rejected with chunk_size="auto" and under a mesh.

    donate_kernel: donate the flat (m, N) state buffers into the Pallas
    `fedgia_update` kernel (`input_output_aliases` + XLA donation), so
    the collapsed diagonal-H update writes in place — no extra (m, N)
    temp in `memory_analysis()` (tests/test_kernels.py). None (default)
    resolves by backend like `donate`: enabled off-CPU, disabled on CPU
    (CPU XLA cannot alias, and the CPU Pallas path is interpret-only).
    Ignored by algorithms without a kernel path.
    """
    if num_rounds <= 0:
        return RoundResult(state, {}, 0, False, 0.0)
    auto_chunk = isinstance(chunk_size, str)
    if auto_chunk:
        if chunk_size != "auto":
            raise ValueError(
                f"chunk_size must be an int or 'auto', got {chunk_size!r}")
        if not scan:
            raise ValueError(
                "chunk_size='auto' tunes the scan chunk length — the "
                "legacy per-round loop (scan=False) has no chunks")
        if mesh is not None:
            # chunks compile lazily under a mesh (GSPMD may re-place carry
            # leaves between chunks, so there is no AOT warm-up) — the
            # candidate timings would measure compilation, not rounds
            raise ValueError(
                "chunk_size='auto' needs AOT-precompiled candidates to "
                "time execution, which the sharded path does not have — "
                "pass a fixed chunk_size under a mesh")
    if clock is not None:
        if participation is not None:
            raise ValueError(
                "clock= and participation= are mutually exclusive: the "
                "clock DERIVES the arrival mask from simulated finish "
                "times (core/clock.py), a policy samples it"
            )
        if clock.m != algo.fed.num_clients:
            raise ValueError(
                f"clock models {clock.m} clients, algorithm has "
                f"{algo.fed.num_clients}"
            )
        async_rounds = True  # a clock IS an arrival process
    if stale_weighting not in api.STALE_WEIGHTINGS:
        raise ValueError(
            f"unknown stale_weighting {stale_weighting!r}: "
            f"{api.STALE_WEIGHTINGS}"
        )
    if stale_weighting != "uniform" and not async_rounds:
        raise ValueError(
            "stale_weighting only applies to async rounds — pass "
            "async_rounds=True (with a participation policy) or clock="
        )
    masked = participation is not None or clock is not None
    if async_rounds:
        if not masked:
            raise ValueError(
                "async_rounds requires an arrival process — a participation "
                "policy (e.g. selection.AvailabilityParticipation) or a "
                "clock (core.clock.ComputeClock)"
            )
        if max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got {max_staleness}")
        if "x" not in state:
            raise ValueError(
                "async_rounds needs the global anchor under state['x'] "
                "(FederatedAlgorithm state contract)"
            )
    flat = flat and hasattr(algo, "round_flat")
    if overlap not in ("off", "scatter"):
        raise ValueError(f"unknown overlap {overlap!r}: ('off', 'scatter')")
    if overlap == "scatter" and not flat:
        raise ValueError(
            "overlap='scatter' splits the flat comm buffer's collective — "
            "it requires the flat round path (flat=True on an algorithm "
            "providing round_flat; drop --no-flat)")
    if donate_kernel is None:
        # same backend rule as carry donation: CPU XLA cannot alias
        # buffers (and the CPU Pallas path is interpret-only)
        donate_kernel = jax.default_backend() != "cpu"
    if store not in ("dense", "active", "offload"):
        raise ValueError(
            f"unknown store {store!r}: ('dense', 'active', 'offload')")
    active_capacity = None
    if store in ("active", "offload"):
        if not flat:
            raise ValueError(
                f"store={store!r} packs the flat (m, N) client buffers — it "
                "requires the flat round path (flat=True on an algorithm "
                "providing round_flat; drop --no-flat)"
            )
        if not masked:
            raise ValueError(
                f"store={store!r} needs a per-round participant set to pack "
                "the tile from — pass participation= (core.selection) or "
                "clock= (core.clock)"
            )
        if not hasattr(algo, "round_flat_active"):
            raise ValueError(
                f"algorithm {getattr(algo, 'name', algo)!r} does not "
                "implement round_flat_active"
            )
        active_capacity = (algo.fed.num_clients if clock is not None
                           else participation.active_capacity)
    if store == "offload":
        if mesh is not None:
            raise ValueError(
                "store='offload' is the single-device host/device split — "
                "under a mesh the resident buffers are already sharded "
                "over devices; pass store='active' instead"
            )
        if overlap != "off":
            raise ValueError(
                "store='offload' runs the host-driven tile loop — the "
                "overlapped-collective carry slot (overlap='scatter') "
                "does not ride it"
            )
        if auto_chunk:
            raise ValueError(
                "chunk_size='auto' tunes the scan chunk length — the "
                "host-driven offload loop (store='offload') has no chunks"
            )
    if aggregate not in ("dense", "packed"):
        raise ValueError(
            f"unknown aggregate {aggregate!r}: ('dense', 'packed')")
    if aggregate == "packed" and store == "dense":
        raise ValueError(
            "aggregate='packed' sums the packed participant tile — it "
            "requires store='active' or store='offload'")
    compressor = compress.as_compressor(
        compression, error_feedback=error_feedback, topk_frac=topk_frac)
    # the clock prices the wire the codec actually produces, even when
    # the identity codec is resolved away below
    wire_comp = compressor
    if compressor is not None and compressor.identity \
            and not compressor.error_feedback:
        # bitwise escape: the identity codec without error feedback IS
        # the uncompressed round — resolve to the same lowered program,
        # not merely the same values
        compressor = None
    if compressor is not None and not flat:
        raise ValueError(
            "compression operates on the flat (m, N) comm buffer — it "
            "requires the flat round path (flat=True on an algorithm "
            "providing round_flat; drop --no-flat)"
        )
    if (faults is not None or screening is not None) and not flat:
        raise ValueError(
            "faults/screening operate on the flat (m, N) comm buffer — "
            "they require the flat round path (flat=True on an algorithm "
            "providing round_flat; drop --no-flat)"
        )
    if faults is not None and faults.num_clients != algo.fed.num_clients:
        raise ValueError(
            f"fault model covers {faults.num_clients} clients, algorithm "
            f"has {algo.fed.num_clients}")
    if quorum:
        if not 0 < quorum <= algo.fed.num_clients:
            raise ValueError(
                f"quorum must be in [0, m={algo.fed.num_clients}], "
                f"got {quorum}")
        if not masked and faults is None and screening is None:
            raise ValueError(
                "quorum needs a source of non-arrival to guard against — "
                "pass participation=, clock=, faults= or screening="
            )
    deadline_clock = (clock is not None
                      and getattr(clock, "deadline_s", None) is not None)
    if deadline_clock and quorum < 1:
        raise ValueError(
            "a deadline clock (ComputeClock(deadline_s=)) can cut rounds "
            "with ZERO arrivals — pass quorum >= 1 so they degrade to "
            "recorded no-ops instead of a 0-client mean"
        )
    if watchdog:
        if watchdog_patience < 1:
            raise ValueError(
                f"watchdog_patience must be >= 1, got {watchdog_patience}")
        if watchdog_factor <= 1.0:
            raise ValueError(
                "watchdog_factor must be > 1 (a divergence threshold "
                f"RELATIVE to the best f̄ seen), got {watchdog_factor}")
        if store == "offload":
            raise ValueError(
                "the watchdog keeps a full state snapshot in the carry — "
                "under store='offload' that would double the host-resident "
                "buffers; run the watchdog with store='dense'/'active'"
            )
    if checkpoint_every < 0:
        raise ValueError(
            f"checkpoint_every must be >= 0, got {checkpoint_every}")
    ckpt_on = checkpoint_every > 0 or resume
    if ckpt_on:
        if checkpoint_dir is None:
            raise ValueError(
                "checkpoint_every/resume need a checkpoint_dir= to write "
                "to / restore from")
        if mesh is not None:
            raise ValueError(
                "checkpointing round-trips the carry through host npz — "
                "not supported under a mesh (GSPMD carry placements); "
                "checkpoint unsharded runs"
            )
        if auto_chunk:
            raise ValueError(
                "chunk_size='auto' picks chunk boundaries from wall-clock "
                "timings — pass a fixed chunk_size when checkpointing so "
                "the save points are deterministic"
            )
        if not scan and store != "offload":
            raise ValueError(
                "checkpointing rides the chunked scan driver (or the "
                "host-driven offload loop) — drop scan=False"
            )
    byte_clock = (clock is not None
                  and getattr(clock, "bandwidth_bps", None) is not None)
    if byte_clock:
        # logical model size BEFORE the lane-padding ravel: the wire
        # never carries padding (core/compress.py)
        model_size = pt.tree_size(state["x"])
        clock = clock.with_wire(
            compress.uplink_bytes(wire_comp, model_size),
            compress.downlink_bytes(model_size),
        )
    if overlap == "scatter" and clock is not None:
        # overlapped rounds pay max(compute, comm) instead of their sum
        clock = clock.with_overlap()
    fp = None
    if ckpt_on:
        fp = _config_fingerprint(
            algo=getattr(algo, "name", type(algo).__name__),
            num_clients=algo.fed.num_clients,
            tol=tol, tol_metric=tol_metric, flat=bool(flat), store=store,
            aggregate=aggregate, overlap=overlap,
            async_rounds=bool(async_rounds), max_staleness=max_staleness,
            stale_weighting=stale_weighting, stale_decay=stale_decay,
            participation=participation, clock=clock, compression=wire_comp,
            error_feedback=bool(error_feedback), topk_frac=topk_frac,
            faults=faults, screening=screening, quorum=quorum,
            watchdog=bool(watchdog), watchdog_patience=watchdog_patience,
            watchdog_factor=watchdog_factor)
    spec = pt.ravel_spec(state["x"]) if flat else None
    if flat:
        # the ONE ravel of the run: everything downstream carries the
        # contiguous buffers; the inverse runs at the return boundary.
        state = flatten_state(algo, state, spec)
        if compressor is not None and compressor.error_feedback \
                and "ef" not in state:
            state["ef"] = jnp.zeros(
                (algo.fed.num_clients, spec.padded_size), spec.dtype)
        if faults is not None and faults.needs_prev \
                and "fault_prev" not in state:
            # the replay fault's stale-upload buffer: engine-created like
            # "ef" above, rides `flat_client_keys` so it shards, offloads
            # and unflattens like any other per-client flat buffer
            state["fault_prev"] = jnp.zeros(
                (algo.fed.num_clients, spec.padded_size), spec.dtype)
        if overlap == "scatter":
            # seed the double-buffered carry slot: row 0 = the initial
            # anchor (== mean(z⁰) for FedGiA, == the barrier's round-0
            # anchor for the baselines), extra rows (algorithm riders,
            # e.g. SCAFFOLD's control-variate delta) = exact zeros.
            rows = int(getattr(algo, "overlap_slot_rows", 1))
            slot0 = state["x"][None]
            if rows > 1:
                slot0 = jnp.concatenate([
                    slot0,
                    jnp.zeros((rows - 1, spec.padded_size), slot0.dtype),
                ])
            state["ovl_shard"] = slot0
    if store != "offload":
        round_fn = make_round_fn(algo, mesh, client_axis, masked=masked,
                                 stale=async_rounds, flat_spec=spec,
                                 active_capacity=active_capacity,
                                 compressor=compressor, overlap=overlap,
                                 donate_kernel=donate_kernel,
                                 aggregate=aggregate,
                                 faults=faults, screening=screening)
    if mesh is not None:
        state, batch = shard_inputs(algo, state, batch, mesh, client_axis)
    if donate is None:
        # CPU XLA cannot alias buffers; donating would only emit warnings
        donate = jax.default_backend() != "cpu"
    stale0 = (
        api.init_stale_xbar(state["x"], algo.fed.num_clients, max_staleness,
                            weighting=stale_weighting, decay=stale_decay)
        if async_rounds else ()
    )
    guard = _make_guard(quorum, watchdog, watchdog_patience, watchdog_factor)
    ws0 = ()
    if watchdog:
        # the snapshot slot starts as a COPY of the initial state: a
        # shared buffer would alias the donated carry's state leaves
        ws0 = {"best": jnp.full((), jnp.inf, jnp.float32),
               "bad": jnp.zeros((), jnp.int32),
               "snap": jax.tree.map(jnp.copy, state)}
    if store == "offload":
        res = _run_offload_loop(
            algo, state, batch, num_rounds, tol, tol_metric,
            participation, clock, stale0, async_rounds, spec,
            active_capacity, compressor, donate_kernel,
            packed=(aggregate == "packed"), max_staleness=max_staleness,
            faults=faults, screening=screening,
            quorum=quorum, checkpoint_every=checkpoint_every,
            checkpoint_dir=checkpoint_dir, resume=resume, fingerprint=fp)
        return dataclasses.replace(
            res, state=unflatten_state(algo, res.state, spec))
    if not scan:
        res = _run_legacy_loop(round_fn, state, batch, num_rounds, tol,
                               tol_metric, participation, stale0,
                               async_rounds, clock, guard=guard, ws0=ws0,
                               donate=donate and mesh is None)
        if flat:
            st = res.state
            if overlap == "scatter":
                st = _finalize_overlap(algo, st)
            res = dataclasses.replace(
                res, state=unflatten_state(algo, st, spec))
        return res
    if auto_chunk:
        chunk_size = AUTO_CHUNK_CANDIDATES[0]
    elif chunk_size <= 0:
        chunk_size = num_rounds if tol <= 0 else min(num_rounds, 32)

    pstate = participation.init() if participation is not None else ()
    cstate = clock.init() if clock is not None else ()

    def call_round(st, b, ps, cs, sl, n):
        """One round + advanced policy/clock/staleness state (from the carry)."""
        if clock is not None:
            mask, now, cs2 = clock.tick(cs, n)
            s2, sl2, met = round_fn(st, b, mask, sl)
            met = _with_staleness_metrics(met, sl2)
            met["sim_time"] = now
            if byte_clock:
                met = _with_byte_metrics(met, mask, clock)
            return s2, ps, cs2, sl2, met
        if not masked:
            s2, met = round_fn(st, b)
            return s2, ps, cs, sl, met
        mask, ps2 = participation.mask(ps, n)
        if async_rounds:
            s2, sl2, met = round_fn(st, b, mask, sl)
            return s2, ps2, cs, sl2, _with_staleness_metrics(met, sl2)
        s2, met = round_fn(st, b, mask)
        return s2, ps2, cs, sl, met

    def guarded_round(st, b, ps, cs, sl, ws, n):
        """One round + the quorum/watchdog guard (identity — and
        structurally absent — when both are off)."""
        s2, ps2, cs2, sl2, met = call_round(st, b, ps, cs, sl, n)
        if guard is not None:
            s2, sl2, ws, met = guard(st, sl, s2, sl2, ws, met)
        return s2, ps2, cs2, sl2, ws, met

    _, _, _, _, _, abs_met = jax.eval_shape(
        guarded_round, state, batch, pstate, cstate, stale0, ws0,
        jnp.zeros((), jnp.int32)
    )

    def chunk_fn(carry, batch, *, length):
        def step(carry, _):
            st, ps, cs, sl, ws, done, n = carry
            if tol > 0:
                def live(op):
                    st_, ps_, cs_, sl_, ws_, b_, n_ = op
                    s2, ps2, cs2, sl2, ws2, met = guarded_round(
                        st_, b_, ps_, cs_, sl_, ws_, n_)
                    return (s2, ps2, cs2, sl2, ws2, met,
                            met[tol_metric] < tol, n_ + 1)

                def frozen(op):
                    st_, ps_, cs_, sl_, ws_, _, n_ = op
                    zeros = jax.tree.map(
                        lambda l: jnp.zeros(l.shape, l.dtype), abs_met
                    )
                    return (st_, ps_, cs_, sl_, ws_, zeros,
                            jnp.ones((), bool), n_)

                s2, ps2, cs2, sl2, ws2, met, d2, n2 = jax.lax.cond(
                    done, frozen, live, (st, ps, cs, sl, ws, batch, n)
                )
            else:
                s2, ps2, cs2, sl2, ws2, met = guarded_round(
                    st, batch, ps, cs, sl, ws, n)
                d2, n2 = done, n + 1
            return (s2, ps2, cs2, sl2, ws2, d2, n2), met

        return jax.lax.scan(step, carry, None, length=length)

    donate_args = (0,) if donate else ()
    if donate:
        # donation must never consume the CALLER's buffers (states are
        # routinely reused across run_rounds calls, e.g. scan-vs-loop
        # comparisons); copy once up front so every donated carry after
        # that is engine-owned.
        state = jax.tree.map(jnp.copy, state)
    chunks: Dict[int, Any] = {}

    def get_chunk(length: int):
        if length not in chunks:
            chunks[length] = jax.jit(
                functools.partial(chunk_fn, length=length),
                donate_argnums=donate_args,
            )
        return chunks[length]

    carry = (state, pstate, cstate, stale0, ws0, jnp.zeros((), bool),
             jnp.zeros((), jnp.int32))

    start_round = 0
    saved_hist = None
    if resume:
        step0 = ckpt_io.latest_step(checkpoint_dir)
        if step0 is not None:
            # fingerprint FIRST (json only): a mismatched config often
            # also means a mismatched carry structure, and the clean
            # error must win over an npz leaf-count assertion
            _check_fingerprint(checkpoint_dir, step0, fp)
            # history dtypes come from abs_met (shapes from the file);
            # the fingerprint guarantees the key set matches
            hist_like = {k: np.zeros((0,), l.dtype)
                         for k, l in abs_met.items()}
            (carry, saved_hist), _ = ckpt_io.load_checkpoint(
                checkpoint_dir, step0, (carry, hist_like))
            start_round = step0

    # chunk_size="auto": the first chunks run the candidate lengths in
    # turn (clipped to the rounds left — the rounds executed are the same
    # whatever the timings), then the fastest per-round candidate drives
    # the remainder.
    plan = None
    if auto_chunk:
        plan, rem_after = [], num_rounds
        for cand in AUTO_CHUNK_CANDIDATES:
            if rem_after <= 0:
                break
            plan.append(min(cand, rem_after))
            rem_after -= plan[-1]

    if mesh is None and not ckpt_on:
        # Pre-compile (AOT) every chunk length this run can need — at most
        # two (fixed chunk) or the candidate set plus each possible
        # remainder (auto) — so wall_s measures execution, matching the
        # legacy warm-up convention. The compiled executables are called
        # directly; on a single device input/output placements are
        # trivially consistent. (Under a mesh, GSPMD may re-place carry
        # leaves between chunks, so there we let jit handle compilation on
        # first call instead. With checkpointing on, chunk lengths are
        # additionally capped at checkpoint boundaries — those compile
        # lazily via get_chunk, so wall_s may include compile time.)
        if auto_chunk:
            lengths = set(plan)
            if tol <= 0 and rem_after > 0:
                # whatever candidate wins, the remainder runs full chunks
                # of it plus one partial chunk
                for cand in set(plan):
                    lengths.add(min(cand, rem_after))
                    if rem_after % cand:
                        lengths.add(rem_after % cand)
        else:
            lengths = {min(chunk_size, num_rounds)}
            if num_rounds % chunk_size and tol <= 0:
                # with tol off the remainder chunk always runs; with tol
                # on, converging runs never reach it, so leave it to
                # compile lazily (get_chunk falls back to plain jit on
                # first call)
                lengths.add(num_rounds % chunk_size)
        abs_of = lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        for length in lengths:
            chunks[length] = get_chunk(length).lower(
                jax.tree.map(abs_of, carry), jax.tree.map(abs_of, batch)
            ).compile()

    chunk_metrics = [] if saved_hist is None else [saved_hist]
    timings = []
    remaining = num_rounds - start_round
    executed = start_round
    next_ckpt = None
    if checkpoint_every > 0:
        next_ckpt = (executed // checkpoint_every + 1) * checkpoint_every
    t0 = time.time()
    while remaining > 0:
        if plan:
            c = plan.pop(0)
            tc = time.time()
            carry, mets = get_chunk(c)(carry, batch)
            jax.block_until_ready(carry[6])
            timings.append(((time.time() - tc) / c, c))
            if not plan:
                chunk_size = min(timings)[1]
        else:
            c = min(chunk_size, remaining)
            if next_ckpt is not None:
                # cut the chunk at the checkpoint boundary so the saved
                # carry sits exactly at a multiple of checkpoint_every —
                # the rounds executed are identical whatever the cuts
                c = min(c, next_ckpt - executed)
            carry, mets = get_chunk(c)(carry, batch)
        chunk_metrics.append(mets)
        remaining -= c
        executed += c
        if next_ckpt is not None and executed == next_ckpt:
            _save_scan_checkpoint(checkpoint_dir, executed, carry,
                                  chunk_metrics, fp)
            next_ckpt += checkpoint_every
        if tol > 0 and bool(carry[5]):  # the chunk's ONE host sync
            break
    state, _, _, _, _, done, n = carry
    jax.block_until_ready(n)
    wall = time.time() - t0

    rounds_run = int(n)
    stopped = tol > 0 and bool(jax.device_get(done))
    mets_host = jax.device_get(chunk_metrics)
    history = {
        k: np.concatenate([np.asarray(m[k]) for m in mets_host])[:rounds_run]
        for k in mets_host[0]
    }
    if flat:
        if overlap == "scatter":
            state = _finalize_overlap(algo, state)
        state = unflatten_state(algo, state, spec)
    return RoundResult(state, history, rounds_run, stopped, wall)


def _finalize_overlap(algo, state):
    """Fold the overlap carry slot back into the state at the return
    boundary: the slot's row 0 holds the LAST round's consensus mean —
    exactly the ``x`` the barrier engine would have stored — and extra
    rows hold algorithm riders. ``algo.overlap_finalize(state, slot)``
    overrides (FedGiA keeps its x — its round stores the consensus it
    used, never lagging; SCAFFOLD also folds the deferred control-variate
    delta); the default recovers ``x = slot[0]``. Runs OUTSIDE the round
    (plain ops on the global, possibly column-sharded slot)."""
    state = dict(state)
    slot = state.pop("ovl_shard")
    fin = getattr(algo, "overlap_finalize", None)
    if fin is not None:
        return fin(state, slot)
    state["x"] = slot[0]
    return state


def _make_guard(quorum: int, watchdog: bool, patience: int, factor: float):
    """Build the post-round QUORUM + WATCHDOG guard, or None when both are
    off (the guarded round is then structurally the unguarded one).

    The guard is pure and traceable — it runs INSIDE the jitted round
    step, so scan == legacy holds for degraded/rollback rounds exactly as
    for ordinary ones:

      * quorum: a round whose accepted-upload count (`screened` when the
        hardening stage ran, else `selected`) falls below `quorum` is a
        recorded no-op — every state entry except the rng and the round
        counter reverts (those two always advance: replaying a round
        index would re-draw the SAME faults/masks forever), the StaleXbar
        reverts with it (the download belongs to the aborted round), and
        the round's history row records `degraded=True`.
      * watchdog: tracks the best f̄ and a full state snapshot; after
        `patience` consecutive committed rounds with f̄ > factor × best
        (NaN counts as diverged), the state rolls back to the snapshot
        (rng/round again excepted) and the row records `rollback=True`.
        Degraded rounds freeze the patience counter — a quorum no-op is
        not evidence of divergence.
    """
    if not quorum and not watchdog:
        return None
    keep = ("rng", "round")

    def merge(flag, a, b, keep=keep):
        """flag ? a : b over two same-structure state dicts; `keep` keys
        always come from `a` (the freshly advanced state)."""
        return {
            k: (a[k] if k in keep else jax.tree.map(
                lambda x, y: jnp.where(flag, x, y), a[k], b[k]))
            for k in a
        }

    def guard(st_old, sl_old, s2, sl2, ws, met):
        met = dict(met)
        ok = jnp.ones((), bool)
        if quorum:
            n_eff = met.get("screened", met["selected"])
            ok = n_eff >= quorum
            s2 = merge(ok, s2, st_old)
            if sl_old != ():
                sl2 = jax.tree.map(
                    lambda a, b: jnp.where(ok, a, b), sl2, sl_old)
            met["degraded"] = jnp.logical_not(ok)
        if watchdog:
            f = met["f_xbar"]
            best, bad, snap = ws["best"], ws["bad"], ws["snap"]
            improved = jnp.logical_and(ok, f < best)
            best2 = jnp.where(improved, f, best)
            snap2 = merge(improved, s2, snap, keep=())
            # NaN f̄ fails the <= and counts as diverged
            diverged = jnp.logical_and(
                ok, jnp.logical_not(f <= jnp.float32(factor) * best2))
            bad2 = jnp.where(ok, jnp.where(diverged, bad + 1, 0), bad)
            roll = bad2 >= patience
            s2 = merge(jnp.logical_not(roll), s2, snap2)
            ws = {"best": best2, "bad": jnp.where(roll, 0, bad2),
                  "snap": snap2}
            met["rollback"] = roll
        return s2, sl2, ws, met

    return guard


def _config_fingerprint(**knobs) -> str:
    """Round-semantics fingerprint embedded in every checkpoint: resume
    refuses a checkpoint written under a different configuration (the
    carry would often deserialize fine, but the continued rounds would
    not be the run the caller asked for). `num_rounds` is deliberately
    NOT part of it — extending a finished run is the point of resuming.
    Deliberately coarse: dataclass knobs (fault model, screening) hash
    by repr, stateful objects (clock, policy, codec) by type + name."""
    def desc(v):
        if v is None or isinstance(v, (bool, int, float, str)):
            return v
        if dataclasses.is_dataclass(v):
            return repr(v)
        return [type(v).__name__, getattr(v, "name", None),
                getattr(v, "deadline_s", None)]

    payload = {k: desc(v) for k, v in knobs.items()}
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()


def _check_fingerprint(checkpoint_dir, step0, fp):
    """Vet the checkpoint's config fingerprint from its json metadata
    alone, BEFORE the carry is deserialized — a config change often also
    changes the carry/history structure, and the leaf-count assertion
    inside load_checkpoint would otherwise mask the real problem."""
    extra = ckpt_io.load_extra(checkpoint_dir, step0)
    if extra.get("fingerprint") != fp:
        raise ValueError(
            f"resume: checkpoint ckpt_{step0:08d} under "
            f"{checkpoint_dir!r} was written by a run with a "
            "different configuration (fingerprint mismatch) — "
            "resuming it would not continue the run it started")


def _save_scan_checkpoint(directory, step, carry, chunk_metrics, fp):
    """Write the scan driver's FULL carry (state incl. ef/fault_prev/
    overlap slot, policy/clock state, StaleXbar, watchdog slot, stop
    flag, round counter) plus the history accumulated so far — one
    atomic npz through checkpoint/checkpoint.py. The history is trimmed
    to the rounds actually run (a tol-stopped chunk emits frozen zero
    rows past the stop), so a resumed run reassembles the exact history
    the uninterrupted run would return."""
    carry_h = jax.device_get(carry)
    n_now = int(carry_h[6])
    mets_host = jax.device_get(chunk_metrics)
    hist = {
        k: np.concatenate([np.asarray(m[k]) for m in mets_host])[:n_now]
        for k in mets_host[0]
    }
    ckpt_io.save_checkpoint(directory, step, (carry_h, hist),
                            extra={"fingerprint": fp})


def _with_byte_metrics(met, mask, clock):
    """Per-round wire totals under a byte-accurate clock: every ARRIVED
    client paid one upload (the codec's wire) and one fp32 download this
    round. Only emitted when `bandwidth_bps` is set — the metric key set
    of plain clocked runs is unchanged."""
    met = dict(met)
    n_arr = jnp.sum(mask.astype(jnp.float32))
    met["bytes_up"] = n_arr * jnp.float32(clock.bytes_up)
    met["bytes_down"] = n_arr * jnp.float32(clock.bytes_down)
    return met


def _with_staleness_metrics(met, stale):
    """Append the async staleness diagnostics to a round's metric dict:
    `staleness` — the (m,) per-client staleness of the anchor each client
    used this round (stacks to a (rounds, m) history) — and its max."""
    met = dict(met)
    met["staleness"] = stale.last_used
    met["staleness_max"] = jnp.max(stale.last_used)
    return met


def _run_legacy_loop(round_fn, state, batch, num_rounds, tol, tol_metric,
                     participation=None, stale0=(), async_rounds=False,
                     clock=None, guard=None, ws0=(), donate=False):
    """Per-round jit dispatch + per-round host sync (the --no-scan path).

    With a participation policy the per-round jitted step also advances the
    policy state and draws the round's mask — the same pure `policy.mask`
    sequence as the scan path, so masks (and results) agree between paths.
    The async `StaleXbar` state, the wall-clock simulation state and the
    quorum/watchdog guard (`_make_guard`, with its watchdog slot `ws0`)
    thread through the step the same way, so async/clock/fault-tolerant
    scan == legacy holds exactly as well.
    """
    if clock is not None:
        byte_clock = getattr(clock, "bandwidth_bps", None) is not None

        def base_step(st, ps, cs, sl, b, n):
            mask, now, cs2 = clock.tick(cs, n)
            s2, sl2, met = round_fn(st, b, mask, sl)
            met = _with_staleness_metrics(met, sl2)
            met["sim_time"] = now
            if byte_clock:
                met = _with_byte_metrics(met, mask, clock)
            return s2, ps, cs2, sl2, met
        pstate, cstate = (), clock.init()
    elif participation is None:
        def base_step(st, ps, cs, sl, b, n):
            s2, met = round_fn(st, b)
            return s2, ps, cs, sl, met
        pstate, cstate = (), ()
    elif async_rounds:
        def base_step(st, ps, cs, sl, b, n):
            mask, ps2 = participation.mask(ps, n)
            s2, sl2, met = round_fn(st, b, mask, sl)
            return s2, ps2, cs, sl2, _with_staleness_metrics(met, sl2)
        pstate, cstate = participation.init(), ()
    else:
        def base_step(st, ps, cs, sl, b, n):
            mask, ps2 = participation.mask(ps, n)
            s2, met = round_fn(st, b, mask)
            return s2, ps2, cs, sl, met
        pstate, cstate = participation.init(), ()

    def step(st, ps, cs, sl, ws, b, n):
        s2, ps2, cs2, sl2, met = base_step(st, ps, cs, sl, b, n)
        if guard is not None:
            s2, sl2, ws, met = guard(st, sl, s2, sl2, ws, met)
        return s2, ps2, cs2, sl2, ws, met

    sstate = stale0
    wstate = ws0
    if donate:
        # Donate the model-size round state — plus the async anchor and
        # the watchdog slot, which also carry model-size buffers — into
        # each per-round dispatch, so the baselines' flat GD rounds (and
        # every other legacy round) update in-place like the scan path's
        # donated carry: no second (m, N) client buffer materialises per
        # round. AOT lower().compile() replaces the executing warm-up
        # (an executed call would consume the donated inputs); the
        # one-time copies keep the caller's arrays valid for round 0.
        state = jax.tree.map(jnp.copy, state)
        sstate = jax.tree.map(jnp.copy, sstate)
        wstate = jax.tree.map(jnp.copy, wstate)
        rfn = jax.jit(step, donate_argnums=(0, 3, 4)).lower(
            state, pstate, cstate, sstate, wstate, batch,
            jnp.zeros((), jnp.int32)).compile()
    else:
        rfn = jax.jit(step)
        # warm-up compile outside the timed region (same convention as the
        # scan path's AOT pre-compile); round is pure, result discarded
        _s, _ps, _cs, _sl, _ws, _m = rfn(state, pstate, cstate, sstate,
                                         wstate, batch,
                                         jnp.zeros((), jnp.int32))
        jax.block_until_ready(_m)
    hist = []
    stopped = False
    t0 = time.time()
    for i in range(num_rounds):
        state, pstate, cstate, sstate, wstate, met = rfn(
            state, pstate, cstate, sstate, wstate, batch, jnp.int32(i))
        met_h = jax.device_get(met)
        hist.append(met_h)
        if tol > 0 and float(met_h[tol_metric]) < tol:
            stopped = True
            break
    wall = time.time() - t0
    history = {k: np.asarray([h[k] for h in hist]) for k in hist[0]} if hist else {}
    return RoundResult(state, history, len(hist), stopped, wall)


def _run_offload_loop(algo, state, batch, num_rounds, tol, tol_metric,
                      participation, clock, stale0, async_rounds,
                      spec, cap, compressor, donate_kernel, packed,
                      max_staleness, faults=None, screening=None,
                      quorum=0, checkpoint_every=0, checkpoint_dir=None,
                      resume=False, fingerprint=None):
    """Host-driven round loop for ``run_rounds(store="offload")``.

    The resident ``flat_client_keys`` buffers, the per-client batch and
    the StaleXbar anchor live HOST-side (`pt.OffloadStore` /
    `pt.host_put`); the device keeps only the globals (x, rng, scalars,
    FedGiA's gram factors) and the compact (m,) per-client riders
    (participation/clock state, staleness ages). Each round:

      1. the jitted SELECT step draws the mask / packed row ids on
         device (the same pure `policy.mask` / `clock.tick` sequence as
         the scan and legacy drivers, so masks agree between paths);
      2. the host gathers the (capacity, N) participant tiles
         (`pt.gather_rows` — the active store's exact clip semantics)
         and moves them to the device;
      3. the jitted TILE ROUND runs `algo.round_flat_active` with a
         tile-mode `ActiveSet` (`tile_state=True`: state accessors are
         the identity on the pre-gathered tiles, while idx/mask keep
         resident row semantics for the aggregation and the dense (m,)
         riders);
      4. the host scatters the updated tiles back (`pt.scatter_rows`,
         drop semantics) and applies the stale-anchor refresh write
         (`anchor[refresh] = x̄` — the identical row select the
         on-device stores run inside the jit).

    Steps 2/3 are DOUBLE-BUFFERED: the next round's mask draw and
    (read-only) batch-tile gather are dispatched while the current
    round's device compute is in flight; only the MUTABLE state tiles
    wait for the current round's scatter. Off-CPU the device-side tiles
    are donated into the round (fresh buffers every round).

    Gather/scatter is pure data movement, so the loop is BITWISE
    ``store="active"`` (tests/test_store.py). FedGiA's population tile
    (`active_tile="population"`) shuttles the full client buffers +
    batch each round instead — every client is rewritten every round,
    so the win is residency (host RAM bounds m), not per-round traffic;
    its gram factors stay device-resident in the globals.

    Both steps are AOT-compiled before the timed region (the legacy
    warm-up convention); the compiled tile round's `memory_analysis`
    (where the backend exposes it) is reported as
    ``RoundResult.extras["device_peak_bytes"]`` next to
    ``host_resident_bytes``.
    """
    population = getattr(algo, "active_tile", "participants") == "population"
    client_keys = tuple(k for k in getattr(algo, "flat_client_keys", ())
                        if k in state)
    byte_clock = (clock is not None
                  and getattr(clock, "bandwidth_bps", None) is not None)
    dev = jax.devices()[0]
    to_dev = lambda tree: jax.tree.map(lambda l: jax.device_put(l, dev), tree)

    store = pt.OffloadStore({k: state[k] for k in client_keys})
    gstate = {k: v for k, v in state.items() if k not in client_keys}
    anchor_h = pt.host_put(stale0.anchor) if async_rounds else None
    if population:
        # every client is rewritten every round: the full batch is read
        # on device each round anyway, so it stays device-resident
        batch_h, batch_dev = None, to_dev(batch)
    else:
        batch_h, batch_dev = pt.host_put_tree(batch), None
    host_bytes = store.nbytes
    if batch_h is not None:
        host_bytes += sum(int(l.nbytes) for l in jax.tree.leaves(batch_h))
    if anchor_h is not None:
        host_bytes += int(anchor_h.nbytes)

    if clock is not None:
        def select(pcs, n):
            mask, now, cs2 = clock.tick(pcs, n)
            return mask, pt.make_active_set(mask, cap).idx, now, cs2
        pcs0 = clock.init()
    else:
        def select(pcs, n):
            mask, ps2 = participation.mask(pcs, n)
            return (mask, pt.make_active_set(mask, cap).idx,
                    jnp.float32(0.0), ps2)
        pcs0 = participation.init()

    def tile_round(gst, tiles, batch_t, mask, sl_in):
        st = dict(gst)
        st.update(tiles)
        aset = pt.make_active_set(mask, cap, tile_state=not population,
                                  packed=packed)
        if async_rounds:
            anchor_t, age, last_used = sl_in
            sl = api.StaleXbar(anchor_t, age, last_used, max_staleness,
                               stale0.weighting, stale0.decay)
            s2, sl2, met = algo.round_flat_active(
                st, batch_t, spec, aset, sl, compressor=compressor,
                donate_kernel=donate_kernel, faults=faults,
                screening=screening)
            met = _with_staleness_metrics(met, sl2)
            refresh = None
            if not population and max_staleness > 0:
                # the rows the host-side anchor write must refresh —
                # the view's exact expression on the exact same inputs
                refresh = jnp.logical_or(mask, age > max_staleness)
            sl_out = (sl2.anchor, sl2.age, sl2.last_used, refresh)
        else:
            s2, met = algo.round_flat_active(
                st, batch_t, spec, aset, compressor=compressor,
                donate_kernel=donate_kernel, faults=faults,
                screening=screening)
            sl_out = ()
        s2 = dict(s2)
        tiles2 = {k: s2.pop(k) for k in client_keys}
        return s2, tiles2, met, sl_out

    abs_of = lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype)
    tile_abs = lambda tree: jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((cap,) + l.shape[1:], l.dtype), tree)
    n0_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pcs_abs = jax.tree.map(abs_of, pcs0)
    mask_abs, _, _, _ = jax.eval_shape(select, pcs_abs, n0_abs)
    select_c = jax.jit(select).lower(pcs_abs, n0_abs).compile()
    if population:
        tiles_abs = {k: abs_of(v) for k, v in store.buffers.items()}
        batch_abs = jax.tree.map(abs_of, batch_dev)
        anchor_abs = abs_of(anchor_h) if async_rounds else None
    else:
        tiles_abs = tile_abs(store.buffers)
        batch_abs = tile_abs(batch_h)
        anchor_abs = (jax.ShapeDtypeStruct((cap,) + anchor_h.shape[1:],
                                           anchor_h.dtype)
                      if async_rounds else None)
    sl_abs = ((anchor_abs, abs_of(stale0.age), abs_of(stale0.last_used))
              if async_rounds else ())
    if jax.default_backend() != "cpu":
        # fresh device buffers every round: tiles + (participants) batch
        # tile + staleness inputs are all donatable; the population batch
        # is reused every round and must stay alive
        dn = (1, 4) if population else (1, 2, 4)
    else:
        dn = ()
    round_c = jax.jit(tile_round, donate_argnums=dn).lower(
        jax.tree.map(abs_of, gstate), tiles_abs, batch_abs, mask_abs,
        sl_abs).compile()

    extras = {"host_resident_bytes": int(host_bytes),
              "device_peak_bytes": None}
    ma_fn = getattr(round_c, "memory_analysis", None)
    if ma_fn is not None:
        try:
            ma = ma_fn()
            extras["device_peak_bytes"] = int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        except Exception:
            pass

    gather_h = lambda tree, i: jax.tree.map(
        lambda l: pt.gather_rows(l, i), tree)
    hist = []
    stopped = False
    age = last_used = None
    if async_rounds:
        age, last_used = stale0.age, stale0.last_used

    def ckpt_tree(pcs_at_round_start):
        """The loop's full host-side state: globals, resident buffers,
        stale anchor + ages, and the policy/clock state AS OF the start
        of the next round (its select re-draws bitwise on resume — the
        draw is a pure function of (pcs, round))."""
        return {"gstate": gstate, "store": store.buffers,
                "anchor": anchor_h if async_rounds else (),
                "age": age if async_rounds else (),
                "last_used": last_used if async_rounds else (),
                "pcs": pcs_at_round_start}

    pcs = pcs0
    start_round = 0
    if resume:
        step0 = ckpt_io.latest_step(checkpoint_dir)
        if step0 is not None:
            _check_fingerprint(checkpoint_dir, step0, fingerprint)
            _, _, met_abs, _ = jax.eval_shape(
                tile_round, jax.tree.map(abs_of, gstate), tiles_abs,
                batch_abs, mask_abs, sl_abs)
            hist_like = {k: np.zeros((0,), l.dtype)
                         for k, l in met_abs.items()}
            if clock is not None:
                hist_like["sim_time"] = np.zeros((0,), np.float32)
                if byte_clock:
                    hist_like["bytes_up"] = np.zeros((0,), np.float32)
                    hist_like["bytes_down"] = np.zeros((0,), np.float32)
            if quorum > 0:
                hist_like["degraded"] = np.zeros((0,), bool)
            (snap, saved_hist), _ = ckpt_io.load_checkpoint(
                checkpoint_dir, step0, (ckpt_tree(pcs0), hist_like))
            gstate = snap["gstate"]
            store.buffers = {k: pt.host_put(v)
                             for k, v in snap["store"].items()}
            if async_rounds:
                anchor_h = pt.host_put(snap["anchor"])
                age, last_used = snap["age"], snap["last_used"]
            pcs = snap["pcs"]
            saved_hist = jax.device_get(saved_hist)
            hist = [{k: saved_hist[k][t] for k in saved_hist}
                    for t in range(step0)]
            start_round = step0
    mask, idx, now, pcs = select_c(pcs, jnp.int32(start_round))
    if population:
        idx_h, staged = None, batch_dev
    else:
        idx_h = pt.host_put(idx)
        staged = to_dev(gather_h(batch_h, idx_h))
    t0 = time.time()
    for i in range(start_round, num_rounds):
        if population:
            tiles = to_dev(store.buffers)
            sl_in = ((to_dev(anchor_h), age, last_used)
                     if async_rounds else ())
        else:
            tiles = to_dev(store.gather_tiles(idx_h))
            sl_in = ((to_dev(pt.gather_rows(anchor_h, idx_h)), age,
                      last_used) if async_rounds else ())
        out = round_c(gstate, tiles, staged, mask, sl_in)
        cur_mask, cur_idx_h, cur_now = mask, idx_h, now
        pcs_prev = pcs
        if i + 1 < num_rounds:
            # double-buffer: next round's mask draw + read-only batch
            # tile overlap the in-flight device round; the mutable state
            # tiles wait for this round's scatter below
            mask, idx, now, pcs = select_c(pcs, jnp.int32(i + 1))
            if not population:
                idx_h = pt.host_put(idx)
                staged = to_dev(gather_h(batch_h, idx_h))
        gstate_new, tiles2, met, sl_out = out
        met = dict(met)
        if clock is not None:
            met["sim_time"] = cur_now
            if byte_clock:
                met = _with_byte_metrics(met, cur_mask, clock)
        degraded = False
        if quorum > 0:
            # the accept/reject decision gates the host-side commit, so
            # the round's count must reach the host BEFORE the scatter —
            # one extra device sync per round, paid only under quorum
            n_eff = met.get("screened", met["selected"])
            degraded = bool(jax.device_get(n_eff) < quorum)
            met["degraded"] = np.asarray(degraded)
        if degraded:
            # recorded no-op (run_rounds' quorum contract): resident
            # tiles, stale anchor and ages keep their pre-round values;
            # only the rng and the round counter advance
            gstate = {k: (gstate_new[k] if k in ("rng", "round")
                          else gstate[k]) for k in gstate_new}
        else:
            gstate = gstate_new
            if population:
                store.buffers = {k: pt.host_put(v)
                                 for k, v in tiles2.items()}
            else:
                store.scatter_tiles(cur_idx_h, tiles2)
            if async_rounds:
                anchor_new, age, last_used, refresh = sl_out
                if population:
                    anchor_h = pt.host_put(anchor_new)
                elif max_staleness > 0:
                    # the dense refresh write, host-side: participant +
                    # force-synced rows take the fresh x̄ — bitwise the
                    # on-device stores' row select (same inputs, same op)
                    anchor_h = jnp.where(
                        pt.host_put(refresh)[:, None],
                        pt.host_put(anchor_new)[None, :], anchor_h)
        met_h = jax.device_get(met)
        hist.append(met_h)
        if tol > 0 and float(met_h[tol_metric]) < tol:
            stopped = True
            break
        if checkpoint_every > 0 and (i + 1) % checkpoint_every == 0:
            # saved AFTER the stop check: a run that stops at a boundary
            # writes no checkpoint for it, so a resume re-runs and
            # re-stops at the same round — bitwise the uninterrupted run
            hist_np = {k: np.asarray([h[k] for h in hist])
                       for k in hist[0]}
            ckpt_io.save_checkpoint(
                checkpoint_dir, i + 1,
                (jax.device_get(ckpt_tree(pcs_prev)), hist_np),
                extra={"fingerprint": fingerprint})
    wall = time.time() - t0
    state_f = dict(gstate)
    for k, b in store.buffers.items():
        state_f[k] = jax.device_put(b, dev)
    history = ({k: np.asarray([h[k] for h in hist]) for k in hist[0]}
               if hist else {})
    return RoundResult(state_f, history, len(hist), stopped, wall, extras)


# --------------------------------------------------------------- generic scan
def scan_steps(step_fn, num_steps: int, *, donate_carry: bool = False):
    """Compile `num_steps` applications of `carry -> (carry, out)` into one
    jitted `lax.scan` — one dispatch for the whole loop. Extra positional
    args are passed through to every step (use for params so they are jit
    arguments, not baked-in constants). Used by the serving decode loop."""

    def run(carry, *args):
        def body(c, _):
            return step_fn(c, *args)

        return jax.lax.scan(body, carry, None, length=num_steps)

    return jax.jit(run, donate_argnums=(0,) if donate_carry else ())
