from repro.core.api import (
    FederatedAlgorithm,
    StaleXbar,
    init_stale_xbar,
    make_algorithm,
    stale_weights,
    stale_xbar_view,
)
from repro.core.clock import (
    ComputeClock,
    LognormalClock,
    TraceClock,
    make_clock,
)
from repro.core.compress import (
    Compressor,
    downlink_bytes,
    make_compressor,
    uplink_bytes,
)
from repro.core.engine import RoundResult, run_rounds, scan_steps
from repro.core.faults import (
    FAULT_KINDS,
    FaultModel,
    FaultSpec,
    Screening,
    make_faults,
)
from repro.core.selection import (
    AvailabilityParticipation,
    CyclicParticipation,
    ParticipationPolicy,
    UniformParticipation,
    WeightedParticipation,
    make_policy,
)
from repro.core.fedgia import FedGiA
from repro.core.baselines.fedavg import FedAvg
from repro.core.baselines.fedprox import FedProx
from repro.core.baselines.fedpd import FedPD
from repro.core.baselines.scaffold import Scaffold
