"""Common protocol for all federated algorithms (FedGiA + baselines).

Client data is handled *stacked*: every batch leaf carries a leading client
axis of size m. Per-client computation is expressed with `jax.vmap` over
that axis, which makes the SAME implementation work
  * single-host (paper reproduction, m=128 tiny clients), and
  * on a pod mesh, where the leading axis is sharded over
    `FedConfig.client_axes` and the aggregation mean lowers to ONE
    parameter-size all-reduce per communication round — the paper's
    communication pattern.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Protocol, Tuple

import jax
import jax.numpy as jnp

LossFn = Callable[[Any, Any], Tuple[jax.Array, Dict[str, jax.Array]]]


class FederatedAlgorithm(Protocol):
    name: str

    def init(self, params0, rng, init_batch=None) -> Dict[str, Any]: ...

    def round(self, state, batch) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]: ...


def broadcast_clients(tree, m: int):
    """Stack m copies of a pytree along a new leading client axis."""
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (m,) + a.shape), tree)


def client_mask(tree_like, mask):
    """Reshape a (m,) mask so it broadcasts against stacked leaves."""
    return jax.tree.map(
        lambda a: mask.reshape((mask.shape[0],) + (1,) * (a.ndim - 1)), tree_like
    )


def per_client_value_and_grad(loss_fn: LossFn):
    """vmap(value_and_grad) over the stacked client batch, shared params."""
    vg = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])
    return jax.vmap(vg, in_axes=(None, 0))


def make_algorithm(fed, loss_fn: LossFn, model=None):
    from repro.core.fedgia import FedGiA
    from repro.core.baselines.fedavg import FedAvg
    from repro.core.baselines.fedprox import FedProx
    from repro.core.baselines.fedpd import FedPD
    from repro.core.baselines.scaffold import Scaffold

    algos = {
        "fedgia": FedGiA,
        "fedavg": FedAvg,
        "fedprox": FedProx,
        "fedpd": FedPD,
        "scaffold": Scaffold,
    }
    if fed.algorithm not in algos:
        raise KeyError(f"unknown algorithm {fed.algorithm!r}: {sorted(algos)}")
    return algos[fed.algorithm](fed, loss_fn, model=model)
