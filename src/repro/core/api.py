"""Common protocol for all federated algorithms (FedGiA + baselines).

Client data is handled *stacked*: every batch leaf carries a leading client
axis of size m. Per-client computation is expressed with `jax.vmap` over
that axis, which makes the SAME implementation work
  * single-host (paper reproduction, m=128 tiny clients), and
  * on a pod mesh, where the leading axis is sharded over
    `FedConfig.client_axes` and the aggregation mean lowers to ONE
    parameter-size all-reduce per communication round — the paper's
    communication pattern.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

LossFn = Callable[[Any, Any], Tuple[jax.Array, Dict[str, jax.Array]]]


class FederatedAlgorithm(Protocol):
    name: str
    # top-level state keys whose leaves carry the leading client axis —
    # the engine shards exactly these (plus the batch) over the mesh.
    client_state_keys: Tuple[str, ...]

    def init(self, params0, rng, init_batch=None) -> Dict[str, Any]: ...

    # `mask` is the engine-drawn participation mask (core/selection.py),
    # already sliced to this shard's local clients; None = the legacy
    # in-algorithm behaviour (FedGiA draws §V.B selection itself, the
    # baselines run full participation).
    def round(
        self, state, batch, mask: Optional[jax.Array] = None
    ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]: ...


# --------------------------------------------------------------------------
# Client-axis context: when the engine runs a round inside `shard_map` with
# the leading client axis split over a mesh axis, every cross-client
# reduction needs a collective. Algorithms express those reductions through
# the helpers below, which are plain single-device ops by default and turn
# into `psum`/`pmax` over the mapped axis inside the engine's sharded round.
# The context is a trace-time constant (set around tracing, not execution),
# so a module-level slot is sufficient.
# --------------------------------------------------------------------------
_CLIENT_AXIS: Optional[Tuple[str, int]] = None  # (mesh axis name, num shards)


@contextlib.contextmanager
def client_sharding(axis_name: str, num_shards: int):
    """Trace `round` bodies with cross-client reductions mapped to `axis_name`."""
    global _CLIENT_AXIS
    prev = _CLIENT_AXIS
    _CLIENT_AXIS = (axis_name, num_shards)
    try:
        yield
    finally:
        _CLIENT_AXIS = prev


def client_axis() -> Optional[str]:
    return _CLIENT_AXIS[0] if _CLIENT_AXIS is not None else None


def local_client_count(m: int) -> int:
    """Clients held by THIS shard (== m unsharded)."""
    if _CLIENT_AXIS is None:
        return m
    axis, shards = _CLIENT_AXIS
    assert m % shards == 0, f"num_clients={m} not divisible by {shards} shards"
    return m // shards


def _mask_bcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Reshape a (m_local,) mask so it broadcasts against a stacked leaf."""
    return mask.reshape((mask.shape[0],) + (1,) * (leaf.ndim - 1))


def client_mean(tree, axis: int = 0, mask: Optional[jax.Array] = None):
    """Mean over the (possibly sharded) leading client axis of a pytree.

    This is eq. (11)'s aggregation: under sharding it lowers to the round's
    ONE model-size all-reduce (`psum` of the local reductions).

    With `mask` (the engine's per-round participation mask, (m_local,)
    bool) it becomes the masked mean over PARTICIPATING clients only:
    sum of masked leaves divided by the participant count. The count rides
    in the same `psum` call as the numerators, so the MODEL-SIZE all-reduce
    count of the round is unchanged — masking adds only a scalar f32[]
    rider (mergeable by XLA's collective combiner; asserted by
    benchmarks/participation_bench.py). On a single device an all-True
    mask is bitwise identical to the unmasked mean (jnp.mean is sum/count
    with the same reduction order); under sharding the two paths reduce
    in different orders (pmean of local means vs psum of local sums) and
    agree only to fp tolerance. Policies guarantee >= 1 participant.
    """
    if mask is None:
        local = jax.tree.map(lambda x: jnp.mean(x, axis=axis), tree)
        if _CLIENT_AXIS is not None:
            name = _CLIENT_AXIS[0]
            local = jax.tree.map(lambda x: jax.lax.pmean(x, name), local)
        return local
    assert axis == 0, "masked client_mean supports leading-axis stacking only"
    num = jax.tree.map(
        lambda x: jnp.sum(jnp.where(_mask_bcast(mask, x), x, 0), axis=0), tree
    )
    cnt = jnp.sum(mask.astype(jnp.float32))
    if _CLIENT_AXIS is not None:
        num, cnt = jax.lax.psum((num, cnt), _CLIENT_AXIS[0])
    return jax.tree.map(lambda s: s / cnt.astype(s.dtype), num)


def client_scalar_mean(x: jax.Array) -> jax.Array:
    """Mean of a per-client (m_local,) scalar array over ALL clients."""
    local = jnp.mean(x)
    if _CLIENT_AXIS is not None:
        local = jax.lax.pmean(local, _CLIENT_AXIS[0])
    return local


def client_scalar_sum(x: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Sum of a per-client scalar array over ALL clients (masked: over
    participating clients only)."""
    local = jnp.sum(x if mask is None else jnp.where(mask, x, 0))
    if _CLIENT_AXIS is not None:
        local = jax.lax.psum(local, _CLIENT_AXIS[0])
    return local


def client_scalar_max(x: jax.Array) -> jax.Array:
    """Max of a scalar over all client shards (no-op unsharded)."""
    if _CLIENT_AXIS is not None:
        x = jax.lax.pmax(x, _CLIENT_AXIS[0])
    return x


def local_client_slice(arr: jax.Array) -> jax.Array:
    """Slice this shard's rows out of a globally-computed (m, ...) array.

    Used for the selection mask: every shard derives the full mask from the
    (replicated) round rng, then keeps its own contiguous block of clients.
    """
    if _CLIENT_AXIS is None:
        return arr
    axis, shards = _CLIENT_AXIS
    m_local = arr.shape[0] // shards
    idx = jax.lax.axis_index(axis)
    return jax.lax.dynamic_slice_in_dim(arr, idx * m_local, m_local, axis=0)


def broadcast_clients(tree, m: int):
    """Stack m copies of a pytree along a new leading client axis."""
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (m,) + a.shape), tree)


def masked_update(mask, new_tree, old_tree):
    """Leaf-wise select over the leading client axis: participating clients
    (mask True) take `new`, frozen clients keep `old`. With an all-True
    mask this is exactly `new_tree` (bitwise), so full participation runs
    are unchanged by the masking plumbing."""
    return jax.tree.map(
        lambda n, o: jnp.where(_mask_bcast(mask, n), n, o), new_tree, old_tree
    )


def per_client_value_and_grad(loss_fn: LossFn):
    """vmap(value_and_grad) over the stacked client batch, shared params."""
    vg = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])
    return jax.vmap(vg, in_axes=(None, 0))


def make_algorithm(fed, loss_fn: LossFn, model=None):
    from repro.core.fedgia import FedGiA
    from repro.core.baselines.fedavg import FedAvg
    from repro.core.baselines.fedprox import FedProx
    from repro.core.baselines.fedpd import FedPD
    from repro.core.baselines.scaffold import Scaffold

    algos = {
        "fedgia": FedGiA,
        "fedavg": FedAvg,
        "fedprox": FedProx,
        "fedpd": FedPD,
        "scaffold": Scaffold,
    }
    if fed.algorithm not in algos:
        raise KeyError(f"unknown algorithm {fed.algorithm!r}: {sorted(algos)}")
    return algos[fed.algorithm](fed, loss_fn, model=model)
