"""Common protocol for all federated algorithms (FedGiA + baselines).

Client data is handled *stacked*: every batch leaf carries a leading client
axis of size m. Per-client computation is expressed with `jax.vmap` over
that axis, which makes the SAME implementation work
  * single-host (paper reproduction, m=128 tiny clients), and
  * on a pod mesh, where the leading axis is sharded over
    `FedConfig.client_axes` and the aggregation mean lowers to ONE
    parameter-size all-reduce per communication round — the paper's
    communication pattern.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.core import compress
from repro.utils import pytree as pt

LossFn = Callable[[Any, Any], Tuple[jax.Array, Dict[str, jax.Array]]]


class FederatedAlgorithm(Protocol):
    """Protocol every federated algorithm in this repo implements.

    State contract: ``init`` returns a dict whose key ``"x"`` holds the
    GLOBAL anchor (the server model / aggregated x̄) and whose
    ``client_state_keys`` entries hold pytrees with a leading client axis
    of size m — the engine shards exactly those (plus the batch) over the
    mesh's client axis.

    Round contract: ``round(state, batch, mask=None, stale=None)`` is pure.

    * ``mask`` — the engine-drawn (m_local,) bool participation mask
      (core/selection.py), already sliced to this shard's clients. True
      means the client participates this round (for FedGiA: runs the
      inexact-ADMM branch). ``None`` = the legacy in-algorithm behaviour
      (FedGiA draws §V.B selection itself, baselines run full
      participation).
    * ``stale`` — a :class:`StaleXbar` carrying each client's possibly
      stale view of the global anchor (async engine,
      ``run_rounds(async_rounds=True)``). When given, ``mask`` must also
      be given (it is the ARRIVAL process) and the round must (a) anchor
      every client's local computation on the per-client view returned by
      :func:`stale_xbar_view` instead of the fresh broadcast, and (b)
      return a 3-tuple ``(state, stale', metrics)`` with the advanced
      staleness state. With ``max_staleness=0`` the view is statically
      the fresh anchor, so the round is bitwise identical to the
      synchronous masked round.
    """

    name: str
    # top-level state keys whose leaves carry the leading client axis —
    # the engine shards exactly these (plus the batch) over the mesh.
    client_state_keys: Tuple[str, ...]
    # Active-store tile shape (``run_rounds(store="active")``):
    #   "participants" — the round reads/writes ONLY the rows of this
    #     round's mask; frozen clients are untouched, so the engine packs
    #     the round down to a (capacity, N) tile (the four baselines).
    #   "population" — the round rewrites every client's state each round
    #     (FedGiA's gradient-descent branch, eqs. (15)-(17), touches every
    #     non-selected client), so the tile is statically the whole
    #     population and the store degenerates to the dense layout with
    #     bitwise-identical results.
    active_tile: str

    def init(self, params0, rng, init_batch=None) -> Dict[str, Any]: ...

    def round_flat_active(
        self, state, batch, spec, active, stale=None
    ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
        """Packed-tile round (``store="active"``): like ``round_flat`` under
        an engine mask, but the round body gathers the (capacity, N) tile
        of ``active.idx`` rows from the resident (m, N) flat client
        buffers, computes on the tile, and scatters the updated rows back,
        so state results are BITWISE the dense masked round's. Population
        diagnostics (``f_xbar``, ``grad_sq_norm``) are redefined as
        participant quantities: the server cannot observe clients it never
        contacted this round (see docs/engine.md#active-set-client-store)."""
        ...

    def round(
        self, state, batch, mask: Optional[jax.Array] = None, stale=None
    ) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]: ...


# --------------------------------------------------------------------------
# Client-axis context: when the engine runs a round inside `shard_map` with
# the leading client axis split over a mesh axis, every cross-client
# reduction needs a collective. Algorithms express those reductions through
# the helpers below, which are plain single-device ops by default and turn
# into `psum`/`pmax` over the mapped axis inside the engine's sharded round.
# The context is a trace-time constant (set around tracing, not execution),
# so a module-level slot is sufficient.
# --------------------------------------------------------------------------
_CLIENT_AXIS: Optional[Tuple[str, int]] = None  # (mesh axis name, num shards)


@contextlib.contextmanager
def client_sharding(axis_name: str, num_shards: int):
    """Trace `round` bodies with cross-client reductions mapped to `axis_name`."""
    global _CLIENT_AXIS
    prev = _CLIENT_AXIS
    _CLIENT_AXIS = (axis_name, num_shards)
    try:
        yield
    finally:
        _CLIENT_AXIS = prev


def client_axis() -> Optional[str]:
    return _CLIENT_AXIS[0] if _CLIENT_AXIS is not None else None


def local_client_count(m: int) -> int:
    """Clients held by THIS shard (== m unsharded)."""
    if _CLIENT_AXIS is None:
        return m
    axis, shards = _CLIENT_AXIS
    assert m % shards == 0, f"num_clients={m} not divisible by {shards} shards"
    return m // shards


def _mask_bcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Reshape a (m_local,) mask so it broadcasts against a stacked leaf."""
    return mask.reshape((mask.shape[0],) + (1,) * (leaf.ndim - 1))


def client_mean(tree, axis: int = 0, mask: Optional[jax.Array] = None,
                weights: Optional[jax.Array] = None):
    """Mean over the (possibly sharded) leading client axis of a pytree.

    This is eq. (11)'s aggregation: under sharding it lowers to the round's
    ONE model-size all-reduce (`psum` of the local reductions).

    With `mask` (the engine's per-round participation mask, (m_local,)
    bool) it becomes the masked mean over PARTICIPATING clients only:
    sum of masked leaves divided by the participant count. The count rides
    in the same `psum` call as the numerators, so the MODEL-SIZE all-reduce
    count of the round is unchanged — masking adds only a scalar f32[]
    rider (mergeable by XLA's collective combiner; asserted by
    benchmarks/participation_bench.py). On a single device an all-True
    mask is bitwise identical to the unmasked mean (jnp.mean is sum/count
    with the same reduction order); under sharding the two paths reduce
    in different orders (pmean of local means vs psum of local sums) and
    agree only to fp tolerance. Policies guarantee >= 1 participant.

    With `weights` (a (m_local,) f32 vector, e.g. `stale_weights`'s decay
    in anchor age) it becomes the normalised weighted mean
    Σ w_i·x_i / Σ w_i — the staleness-aware reading of eq. (11) where old
    z_i are downweighted instead of averaged uniformly. A mask folds into
    the weights (masked-out clients get weight 0) and the weight sum rides
    in the SAME psum as the numerators, so the round still issues exactly
    one model-size all-reduce (HLO-asserted in tests/test_wallclock.py).
    `weights=None` keeps the unweighted paths above BITWISE — uniform
    staleness weighting passes None, which is why it is free.
    """
    if weights is None:
        if mask is None:
            local = jax.tree.map(lambda x: jnp.mean(x, axis=axis), tree)
            if _CLIENT_AXIS is not None:
                name = _CLIENT_AXIS[0]
                local = jax.tree.map(lambda x: jax.lax.pmean(x, name), local)
            return local
        assert axis == 0, "masked client_mean supports leading-axis stacking only"
        num = jax.tree.map(
            lambda x: jnp.sum(jnp.where(_mask_bcast(mask, x), x, 0), axis=0), tree
        )
        cnt = jnp.sum(mask.astype(jnp.float32))
        if _CLIENT_AXIS is not None:
            num, cnt = jax.lax.psum((num, cnt), _CLIENT_AXIS[0])
        return jax.tree.map(lambda s: s / cnt.astype(s.dtype), num)
    assert axis == 0, "weighted client_mean supports leading-axis stacking only"
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = jnp.where(mask, w, 0.0)
    num = jax.tree.map(
        lambda x: jnp.sum(_mask_bcast(w, x).astype(x.dtype) * x, axis=0), tree
    )
    den = jnp.sum(w)
    if _CLIENT_AXIS is not None:
        num, den = jax.lax.psum((num, den), _CLIENT_AXIS[0])
    return jax.tree.map(lambda s: s / den.astype(s.dtype), num)


def client_scalar_mean(x: jax.Array) -> jax.Array:
    """Mean of a per-client (m_local,) scalar array over ALL clients."""
    local = jnp.mean(x)
    if _CLIENT_AXIS is not None:
        local = jax.lax.pmean(local, _CLIENT_AXIS[0])
    return local


def client_scalar_sum(x: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Sum of a per-client scalar array over ALL clients (masked: over
    participating clients only)."""
    local = jnp.sum(x if mask is None else jnp.where(mask, x, 0))
    if _CLIENT_AXIS is not None:
        local = jax.lax.psum(local, _CLIENT_AXIS[0])
    return local


def client_scalar_max(x: jax.Array) -> jax.Array:
    """Max of a scalar over all client shards (no-op unsharded)."""
    if _CLIENT_AXIS is not None:
        x = jax.lax.pmax(x, _CLIENT_AXIS[0])
    return x


def local_client_slice(arr: jax.Array) -> jax.Array:
    """Slice this shard's rows out of a globally-computed (m, ...) array.

    Used for the selection mask: every shard derives the full mask from the
    (replicated) round rng, then keeps its own contiguous block of clients.
    """
    if _CLIENT_AXIS is None:
        return arr
    axis, shards = _CLIENT_AXIS
    m_local = arr.shape[0] // shards
    idx = jax.lax.axis_index(axis)
    return jax.lax.dynamic_slice_in_dim(arr, idx * m_local, m_local, axis=0)


def broadcast_clients(tree, m: int):
    """Stack m copies of a pytree along a new leading client axis."""
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (m,) + a.shape), tree)


def masked_update(mask, new_tree, old_tree):
    """Leaf-wise select over the leading client axis: participating clients
    (mask True) take `new`, frozen clients keep `old`. With an all-True
    mask this is exactly `new_tree` (bitwise), so full participation runs
    are unchanged by the masking plumbing."""
    return jax.tree.map(
        lambda n, o: jnp.where(_mask_bcast(mask, n), n, o), new_tree, old_tree
    )


def flat_grad_sq_norm(grads_flat: jax.Array, spec) -> jax.Array:
    """The `grad_sq_norm` diagnostic ||(1/m) Σ_i ∇f_i||² over the FLAT
    (m_local, N) gradient buffer, without a model-size all-reduce.

    Unsharded this unravels the all-client gradient mean and takes the
    pytree sq-norm — BITWISE the pytree path's
    ``tree_sq_norm(client_mean(grads))`` (per-leaf vdot accumulation in
    treedef order, which a whole-buffer vdot would not reproduce).

    Under client sharding the metric only needs the SCALAR norm, never the
    replicated mean, so the full `psum` of the (N,) gradient sum is
    replaced by the cheaper `psum_scatter`: each shard receives one
    contiguous chunk of the global gradient sum, squares it locally, and a
    scalar psum of the chunk norms yields ||Σ||²/m². The lowered HLO
    contains a reduce-scatter + a scalar all-reduce — NO second model-size
    all-reduce, which is what keeps the flat sharded round at exactly one
    (tests/test_flat.py). Falls back to a full psum when the buffer does
    not divide over the shards (never the case for the LANES-padded spec
    with power-of-two shard counts)."""
    if _CLIENT_AXIS is None:
        return pt.tree_sq_norm(spec.unravel(jnp.mean(grads_flat, axis=0)))
    name, shards = _CLIENT_AXIS
    m_global = grads_flat.shape[0] * shards
    g_sum = jnp.sum(grads_flat, axis=0)
    if g_sum.shape[-1] % shards == 0:
        chunk = jax.lax.psum_scatter(g_sum, name, scatter_dimension=0,
                                     tiled=True)
        sq = jax.lax.psum(jnp.vdot(chunk, chunk), name)
    else:
        total = jax.lax.psum(g_sum, name)
        sq = jnp.vdot(total, total)
    return sq / jnp.float32(m_global) ** 2


def flat_round_aggregate(contrib, grads, losses, sel_vec, spec,
                         mask: Optional[jax.Array] = None,
                         weights: Optional[jax.Array] = None,
                         extra_mean: Optional[jax.Array] = None):
    """Eq. (11) + the round's diagnostics over the FLAT client buffer, in
    ONE collective (used by the four baselines' flat rounds, whose local
    trajectories — unlike FedGiA's z — are already functions of this
    round's gradients, so aggregation and diagnostics can share a psum).

    `contrib` is the (m_local, N) flat client contribution, `grads` the
    (m_local, N) flat raw per-client gradients, `losses` the (m_local,)
    per-client loss and `sel_vec` the (m_local,) participation indicator
    (pre-masked) for the `selected` metric. `extra_mean` optionally rides
    one more (m_local, N) buffer through the same psum as a plain
    all-client mean (SCAFFOLD's control-variate delta). Returns
    ``(agg, grad_sq_norm, f_mean, n_sel[, extra])``.

    Unsharded this is exactly `client_mean` + `jnp.mean` / `jnp.sum` +
    :func:`flat_grad_sq_norm` — BITWISE the pytree path's reductions on
    the raveled layout. Under client sharding every local partial sum
    rides a single `jax.lax.psum` tuple and the gradient norm goes
    through `flat_grad_sq_norm`'s reduce-scatter, so the lowered round
    contains exactly ONE (model-size) all-reduce instruction — eq. (11)
    as one contiguous communication (HLO-asserted in tests/test_flat.py).
    The fused psum sums local SUMS instead of pmean-ing local means, so
    the sharded flat round matches the sharded pytree round only to fp
    tolerance (same caveat as `client_mean(mask=...)`)."""
    gsq = flat_grad_sq_norm(grads, spec)
    if _CLIENT_AXIS is None:
        agg = client_mean(contrib, mask=mask, weights=weights)
        out = (agg, gsq, jnp.mean(losses), jnp.sum(sel_vec))
        if extra_mean is not None:
            out = out + (jnp.mean(extra_mean, axis=0),)
        return out
    name, shards = _CLIENT_AXIS
    m_global = contrib.shape[0] * shards
    if weights is not None:
        w = weights.astype(jnp.float32)
        if mask is not None:
            w = jnp.where(mask, w, 0.0)
        num = jnp.sum(w[:, None].astype(contrib.dtype) * contrib, axis=0)
        den = jnp.sum(w)
    elif mask is not None:
        num = jnp.sum(jnp.where(mask[:, None], contrib, 0), axis=0)
        den = jnp.sum(mask.astype(jnp.float32))
    else:
        num = jnp.sum(contrib, axis=0)
        den = None  # static m_global, no rider needed
    n_buf = num.shape[0]
    if extra_mean is not None:
        # concatenate the rider onto the numerator: ONE all-reduce
        # instruction even when the backend skips the collective combiner
        num = jnp.concatenate(
            [num, jnp.sum(extra_mean, axis=0).astype(num.dtype)])
    local = (num, jnp.sum(losses), jnp.sum(sel_vec))
    if den is not None:
        local = local + (den,)
    red = jax.lax.psum(local, name)  # the round's ONE all-reduce
    den_red = (red[3].astype(red[0].dtype) if den is not None
               else jnp.asarray(m_global, red[0].dtype))
    agg = red[0][:n_buf] / den_red
    out = (agg, gsq, red[1] / m_global, red[2])
    if extra_mean is not None:
        out = out + (red[0][n_buf:] / m_global,)
    return out


def flat_grad_sq_norm_active(grads_tile: jax.Array, active,
                             spec) -> jax.Array:
    """Participant-gradient diagnostic ||(1/|C|) Σ_{i∈C} ∇f_i||² over the
    packed (capacity, N) gradient tile.

    This is the active-store reading of ``grad_sq_norm``: the server never
    contacted the frozen clients this round, so the population gradient of
    the dense path is unobservable — the tol stopping rule gates on the
    participants' mean gradient instead (documented in docs/engine.md).
    Padding rows are zeroed (exact identities of the sum). Under client
    sharding the participant count rides the existing scalar psum next to
    the reduce-scattered chunk norm, so the round still issues no second
    model-size all-reduce."""
    g_masked = active.zero_invalid(grads_tile)
    if _CLIENT_AXIS is None:
        g_mean = jnp.sum(g_masked, axis=0) / active.count.astype(
            g_masked.dtype
        )
        return pt.tree_sq_norm(spec.unravel(g_mean))
    name, _ = _CLIENT_AXIS
    g_sum = jnp.sum(g_masked, axis=0)
    if g_sum.shape[-1] % _CLIENT_AXIS[1] == 0:
        chunk = jax.lax.psum_scatter(g_sum, name, scatter_dimension=0,
                                     tiled=True)
        sq, cnt = jax.lax.psum((jnp.vdot(chunk, chunk), active.count), name)
    else:
        total, cnt = jax.lax.psum((g_sum, active.count), name)
        sq = jnp.vdot(total, total)
    return sq / cnt.astype(jnp.float32) ** 2


def flat_round_aggregate_active(contrib_tile, grads_tile, losses_tile,
                                active, spec,
                                weights: Optional[jax.Array] = None,
                                extra_mean_tile: Optional[jax.Array] = None):
    """Eq. (11) + diagnostics over the PACKED participant tile, in ONE
    collective — the active-store twin of :func:`flat_round_aggregate`.

    All tile arguments are (capacity, ...) with ``active.idx`` row order.
    The aggregate ``agg`` and the ``extra`` rider are BITWISE the dense
    masked path's. Packed-order sums cannot deliver that on their own —
    XLA reduces an m-row and a capacity-row buffer with different
    accumulator associations (strided multi-accumulator loops), so the
    two differ by ~1 ulp — hence on a single device the tile is first
    SCATTERED back to the dense (m, N) layout (zeros at frozen rows,
    exactly the dense path's masked values, bit for bit) and the dense
    reduction expressions run on it unchanged: same input bits + same
    compiled reduce = same output bits. Eq. (11) therefore remains one
    O(m·N) streaming reduction per round; the active store's saving is
    the per-client WORK (trajectories, gradient evaluations: O(capacity)
    instead of O(m)), not the final aggregation pass. The diagnostics
    differ by construction: ``f_mean`` is the participant loss mean and
    ``grad_sq_norm`` the participant gradient norm
    (:func:`flat_grad_sq_norm_active`), because the dense versions
    average over clients the active round never touches. ``weights`` are
    the DENSE (m_local,) staleness weights (:func:`stale_weights`);
    ``extra_mean_tile`` rides as a plain all-client mean (SCAFFOLD's
    control-variate delta, exact zeros on frozen clients). Under client
    sharding the local tuple keeps the packed O(capacity) sums and rides
    a single `jax.lax.psum` — exactly ONE model-size all-reduce
    (HLO-asserted in tests/test_flat.py), fp-equal to the dense sharded
    round (which is itself only fp-equal to unsharded, same caveat as
    :func:`flat_round_aggregate`)."""
    gsq = flat_grad_sq_norm_active(grads_tile, active, spec)
    losses_z = active.zero_invalid(losses_tile)
    n_sel = active.count
    loss_sum = jnp.sum(losses_z)
    if _CLIENT_AXIS is None:
        if active.packed:
            # Opt-in fp-tolerance mode (run_rounds(aggregate="packed")):
            # sum the (capacity, N) tile directly — O(capacity·N), the
            # sharded branch's math on one device, skipping the dense
            # (m, N) scatter temp entirely. ~1 ulp from the bitwise
            # dense default (docs/engine.md#packed-aggregation).
            contrib_z = active.zero_invalid(contrib_tile)
            if weights is not None:
                w_t = jnp.where(
                    active.valid,
                    active.gather(
                        jnp.where(active.mask, weights, 0.0)
                    ).astype(jnp.float32),
                    0.0,
                )
                num = jnp.sum(
                    w_t[:, None].astype(contrib_z.dtype) * contrib_z, axis=0
                )
                den = jnp.sum(w_t)
            else:
                num = jnp.sum(contrib_z, axis=0)
                den = active.count
            agg = num / den.astype(num.dtype)
            out = (agg, gsq, loss_sum / n_sel, n_sel)
            if extra_mean_tile is not None:
                extra = jnp.sum(
                    active.zero_invalid(extra_mean_tile), axis=0
                ) / active.num_clients
                out = out + (extra,)
            return out
        m = active.num_clients
        zeros = jnp.zeros((m,) + contrib_tile.shape[1:], contrib_tile.dtype)
        contrib_d = active.scatter(zeros, contrib_tile)
        mask = active.mask
        if weights is not None:
            w = jnp.where(mask, weights.astype(jnp.float32), 0.0)
            num = jnp.sum(
                w[:, None].astype(contrib_d.dtype) * contrib_d, axis=0
            )
            den = jnp.sum(w)
        else:
            num = jnp.sum(jnp.where(mask[:, None], contrib_d, 0), axis=0)
            den = active.count
        agg = num / den.astype(num.dtype)
        out = (agg, gsq, loss_sum / n_sel, n_sel)
        if extra_mean_tile is not None:
            extra_d = active.scatter(
                jnp.zeros_like(contrib_d), extra_mean_tile
            )
            out = out + (jnp.mean(extra_d, axis=0),)
        return out
    name, shards = _CLIENT_AXIS
    m_global = active.num_clients * shards
    contrib_z = active.zero_invalid(contrib_tile)
    if weights is not None:
        w_t = jnp.where(
            active.valid,
            active.gather(jnp.where(active.mask, weights, 0.0)).astype(
                jnp.float32
            ),
            0.0,
        )
        num = jnp.sum(w_t[:, None].astype(contrib_z.dtype) * contrib_z,
                      axis=0)
        den = jnp.sum(w_t)
    else:
        num = jnp.sum(contrib_z, axis=0)
        den = active.count
    n_buf = num.shape[0]
    if extra_mean_tile is not None:
        num = jnp.concatenate([
            num,
            jnp.sum(active.zero_invalid(extra_mean_tile), axis=0).astype(
                num.dtype
            ),
        ])
    local = (num, loss_sum, n_sel, den)
    red = jax.lax.psum(local, name)  # the round's ONE all-reduce
    agg = red[0][:n_buf] / red[3].astype(red[0].dtype)
    out = (agg, gsq, red[1] / red[2], red[2])
    if extra_mean_tile is not None:
        out = out + (red[0][n_buf:] / m_global,)
    return out


def flat_overlap_consensus(slot: jax.Array) -> jax.Array:
    """Materialise the consensus from the overlap carry slot
    (``run_rounds(overlap="scatter")``): the deferred half of eq. (11).

    ``slot`` holds the PREVIOUS round's aggregation results as normalised
    (rows, N) means — row 0 is x̄, extra rows are algorithm riders
    (SCAFFOLD's control-variate delta). Under client sharding each shard
    carries only its (rows, N/shards) column chunk (the output layout of
    :func:`flat_overlap_aggregate`'s reduce-scatter), and this helper is
    the round's one model-size `all_gather` — issued at the round TOP, so
    XLA can overlap the previous round's reduce-scatter with the compute
    between them. Unsharded the slot is already the full buffer and this
    is the identity (the overlap pipeline is then a pure carry-layout
    change: bitwise the barrier round, tests/test_overlap.py)."""
    if _CLIENT_AXIS is None:
        return slot
    return jax.lax.all_gather(slot, _CLIENT_AXIS[0], axis=1, tiled=True)


def flat_overlap_aggregate(contrib, grads, losses, sel_vec, spec,
                           mask: Optional[jax.Array] = None,
                           weights: Optional[jax.Array] = None,
                           extra_mean: Optional[jax.Array] = None):
    """Eq. (11) as the EARLY half of the split collective: reduce this
    round's contributions into the next round's carry slot, in ONE
    model-size `reduce-scatter` (`run_rounds(overlap="scatter")`).

    The overlap twin of :func:`flat_round_aggregate`: same arguments, but
    instead of returning the replicated aggregate it returns
    ``(slot', grad_sq_norm, f_mean, n_sel)`` where ``slot'`` is the new
    carry slot — row 0 the normalised contribution mean, optional
    ``extra_mean`` rows next (all-client means). The NEXT round reads the
    consensus back via :func:`flat_overlap_consensus`'s all-gather, so a
    round issues exactly one reduce-scatter (here, at the round END) plus
    one all-gather (at the round TOP) and ZERO model-size all-reduces —
    the two halves of eq. (11)'s psum, pulled apart so the local compute
    between them hides the wire (HLO-asserted in tests/test_overlap.py).

    The gradient-norm diagnostic cannot call :func:`flat_grad_sq_norm`
    here — its psum_scatter would be a SECOND model-size reduce-scatter —
    so the raw gradient sum rides as one more stacked row: each shard
    squares its column chunk of the scattered sum and a scalar psum
    (riding with the loss/selected/weight scalars) yields ||Σ∇f_i/m||².

    Unsharded this DELEGATES to :func:`flat_round_aggregate` and stacks
    its outputs into the slot — the overlapped engine is then bitwise the
    barrier engine (the slot is written at round end and read unchanged
    at the next round top). Under sharding the reduce-scatter splits
    eq. (11)'s sum across shards column-wise, which reassociates the
    reduction exactly like the fused psum does — fp tolerance vs
    unsharded, same caveat as :func:`flat_round_aggregate`."""
    if _CLIENT_AXIS is None:
        out = flat_round_aggregate(contrib, grads, losses, sel_vec, spec,
                                   mask=mask, weights=weights,
                                   extra_mean=extra_mean)
        rows = [out[0]] if extra_mean is None else [out[0], out[4]]
        return jnp.stack(rows), out[1], out[2], out[3]
    name, shards = _CLIENT_AXIS
    m_global = contrib.shape[0] * shards
    n = contrib.shape[-1]
    assert n % shards == 0, (
        f"overlap reduce-scatter needs padded_size {n} divisible by "
        f"{shards} shards (run_rounds validates this at setup)")
    if weights is not None:
        w = weights.astype(jnp.float32)
        if mask is not None:
            w = jnp.where(mask, w, 0.0)
        num = jnp.sum(w[:, None].astype(contrib.dtype) * contrib, axis=0)
        den = jnp.sum(w)
    elif mask is not None:
        num = jnp.sum(jnp.where(mask[:, None], contrib, 0), axis=0)
        den = jnp.sum(mask.astype(jnp.float32))
    else:
        num = jnp.sum(contrib, axis=0)
        den = None  # static m_global, no rider needed
    rows = [num]
    if extra_mean is not None:
        rows.append(jnp.sum(extra_mean, axis=0).astype(num.dtype))
    g_sum = jnp.sum(grads, axis=0)
    stacked = jnp.stack(rows + [g_sum.astype(num.dtype)])
    # the round's ONE model-size reduce-scatter: every shard receives its
    # contiguous column chunk of the globally-summed rows
    chunks = jax.lax.psum_scatter(stacked, name, scatter_dimension=1,
                                  tiled=True)
    g_chunk = chunks[-1]
    scalars = (jnp.vdot(g_chunk, g_chunk), jnp.sum(losses),
               jnp.sum(sel_vec))
    if den is not None:
        scalars = scalars + (den,)
    red = jax.lax.psum(scalars, name)  # scalar riders, not model-size
    den_red = (red[3].astype(chunks.dtype) if den is not None
               else jnp.asarray(m_global, chunks.dtype))
    slot_rows = [chunks[0] / den_red]
    if extra_mean is not None:
        slot_rows.append(chunks[1] / m_global)
    gsq = red[0] / jnp.float32(m_global) ** 2
    return jnp.stack(slot_rows), gsq, red[1] / m_global, red[2]


def flat_overlap_aggregate_active(contrib_tile, grads_tile, losses_tile,
                                  active, spec,
                                  weights: Optional[jax.Array] = None,
                                  extra_mean_tile: Optional[jax.Array] = None):
    """Active-store twin of :func:`flat_overlap_aggregate`: the packed
    (capacity, N) participant tile reduced into the next round's carry
    slot with ONE model-size reduce-scatter.

    Same argument contract as :func:`flat_round_aggregate_active` (tile
    rows in ``active.idx`` order, dense ``weights``); returns
    ``(slot', grad_sq_norm, f_mean, n_sel)`` with the participant
    diagnostics of the active store (loss mean and gradient norm over the
    clients the server actually contacted). Unsharded it DELEGATES to the
    barrier aggregate — bitwise the active barrier round. Under sharding
    the zeroed tile sums ride the stacked reduce-scatter and the
    participant count/weight sum ride the scalar psum, so the round keeps
    the one-RS + one-AG collective budget of the dense overlap round."""
    if _CLIENT_AXIS is None:
        out = flat_round_aggregate_active(contrib_tile, grads_tile,
                                          losses_tile, active, spec,
                                          weights=weights,
                                          extra_mean_tile=extra_mean_tile)
        rows = [out[0]] if extra_mean_tile is None else [out[0], out[4]]
        return jnp.stack(rows), out[1], out[2], out[3]
    name, shards = _CLIENT_AXIS
    m_global = active.num_clients * shards
    contrib_z = active.zero_invalid(contrib_tile)
    n = contrib_z.shape[-1]
    assert n % shards == 0, (
        f"overlap reduce-scatter needs padded_size {n} divisible by "
        f"{shards} shards (run_rounds validates this at setup)")
    if weights is not None:
        w_t = jnp.where(
            active.valid,
            active.gather(jnp.where(active.mask, weights, 0.0)).astype(
                jnp.float32
            ),
            0.0,
        )
        num = jnp.sum(w_t[:, None].astype(contrib_z.dtype) * contrib_z,
                      axis=0)
        den = jnp.sum(w_t)
    else:
        num = jnp.sum(contrib_z, axis=0)
        den = active.count
    rows = [num]
    if extra_mean_tile is not None:
        rows.append(
            jnp.sum(active.zero_invalid(extra_mean_tile), axis=0).astype(
                num.dtype))
    g_sum = jnp.sum(active.zero_invalid(grads_tile), axis=0)
    stacked = jnp.stack(rows + [g_sum.astype(num.dtype)])
    # the round's ONE model-size reduce-scatter
    chunks = jax.lax.psum_scatter(stacked, name, scatter_dimension=1,
                                  tiled=True)
    g_chunk = chunks[-1]
    loss_sum = jnp.sum(active.zero_invalid(losses_tile))
    scalars = (jnp.vdot(g_chunk, g_chunk), loss_sum, active.count, den)
    red = jax.lax.psum(scalars, name)  # scalar riders, not model-size
    slot_rows = [chunks[0] / red[3].astype(chunks.dtype)]
    if extra_mean_tile is not None:
        slot_rows.append(chunks[1] / m_global)
    gsq = red[0] / red[2].astype(jnp.float32) ** 2
    return jnp.stack(slot_rows), gsq, red[1] / red[2], red[2]


def _compress_row_ids(m_local: int) -> jax.Array:
    """GLOBAL client row ids for this shard's (m_local,) block — the
    stochastic-rounding key of client i must be the same whether the
    round runs unsharded or inside `shard_map` (sharded rounds would
    otherwise draw identical noise for different clients)."""
    ids = jnp.arange(m_local, dtype=jnp.uint32)
    if _CLIENT_AXIS is not None:
        name, _ = _CLIENT_AXIS
        ids = ids + jax.lax.axis_index(name).astype(jnp.uint32) * m_local
    return ids


def compress_upload(compressor, contrib: jax.Array,
                    ef: Optional[jax.Array], spec, *,
                    key: Optional[jax.Array] = None,
                    mask: Optional[jax.Array] = None,
                    row_ids: Optional[jax.Array] = None):
    """The round's uplink through a codec (core/compress.py): returns
    ``(decoded, ef')`` where ``decoded`` is the server-visible fp32
    decode of each client's upload and ``ef'`` the advanced per-client
    error-feedback residual (None when ``ef`` is None).

    Semantics per client i: the upload is u_i = contrib_i + e_i (the
    residual folds the PREVIOUS rounds' compression error back in), the
    server sees C(u_i), and the new residual is e_i' = u_i - C(u_i) — so
    decoded uploads + final residual telescope to the raw uploads
    exactly (tests/test_compress.py). With ``mask``, masked-out clients
    did not upload this round: their residual is frozen (their decoded
    row is computed but never enters the masked aggregation).

    This is DECOMPRESS-BEFORE-REDUCE: encode+decode are shard-local
    elementwise/per-row ops (no collectives), the fp32 ``decoded`` is
    what flows into eq. (11)'s psum, so the round still lowers to
    exactly ONE model-size all-reduce under client sharding. The decode
    of the lane-padded tail is forced back to exact zero (the wire
    carries only the ``spec.size`` logical lanes), preserving the
    RavelSpec zero-tail invariant under affine codecs.

    ``key`` (stochastic codecs): the round-replicated base key
    (`compress.round_key`); per-client keys are derived from GLOBAL row
    ids (``row_ids`` overrides, e.g. the active store's ``active.idx``),
    so sharded and unsharded rounds quantize with identical noise.
    """
    u = contrib if ef is None else contrib + ef
    keys = None
    if compressor.stochastic:
        assert key is not None, (
            f"{compressor.name} uses stochastic rounding and needs the "
            "round key (compress.round_key)")
        ids = row_ids if row_ids is not None else _compress_row_ids(
            u.shape[0])
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            ids.astype(jnp.uint32))
    dec = compressor.encode_decode(u, keys=keys, n=spec.size)
    if spec.padded_size != spec.size:
        lane = jnp.arange(u.shape[-1]) < spec.size
        dec = jnp.where(lane, dec, jnp.zeros_like(dec))
    if ef is None:
        return dec, None
    ef_new = u - dec
    if mask is not None:
        ef_new = jnp.where(_mask_bcast(mask, ef_new), ef_new, ef)
    return dec, ef_new


def compress_upload_active(compressor, contrib_tile: jax.Array,
                           ef: Optional[jax.Array], active, spec, *,
                           key: Optional[jax.Array] = None):
    """Active-store twin of :func:`compress_upload`: the codec runs on
    the packed (capacity, N) participant tile only — exactly the
    clients that upload this round. The residual rows of the
    participants are GATHERED from the dense resident ``ef`` buffer,
    advanced on the tile, and SCATTERED back (padding rows carry the
    sentinel index and are dropped, so frozen clients' residuals are
    untouched — the dense path's mask freeze, row for row). Per-client
    stochastic keys come from the tile's resident row ids, so tile and
    dense rounds quantize each client identically. Returns
    ``(decoded_tile, ef')`` with ``ef'`` the full dense residual — or the
    updated residual TILE under the host-offloaded store
    (``active.tile_state``), whose engine scatters it back host-side."""
    ef_t = None if ef is None else active.gather_state(ef)
    ids = active.idx.astype(jnp.uint32)
    if _CLIENT_AXIS is not None:
        name, _ = _CLIENT_AXIS
        m_local = active.num_clients
        ids = ids + jax.lax.axis_index(name).astype(jnp.uint32) * m_local
    dec_t, ef_new_t = compress_upload(
        compressor, contrib_tile, ef_t, spec, key=key, row_ids=ids)
    if ef is None:
        return dec_t, None
    return dec_t, active.scatter_state(ef, ef_new_t)


def harden_upload(contrib: jax.Array, mask: Optional[jax.Array], spec, *,
                  faults=None, screening=None,
                  fault_prev: Optional[jax.Array] = None,
                  round_idx: Optional[jax.Array] = None):
    """The round's fault-injection + screening stage (core/faults.py),
    between the codec decode and eq. (11)'s aggregation.

    Applies the :class:`~repro.core.faults.FaultModel` to the decoded
    (m_local, N) upload (crashed rows leave the mask and are zeroed; the
    replay buffer ``fault_prev`` advances like the EF residual), then the
    :class:`~repro.core.faults.Screening` finite check + norm clip. Both
    stages are shard-local elementwise/per-row ops keyed on GLOBAL row
    ids — no collectives — so the caller's aggregation still lowers to
    the round's ONE model-size collective set; the screened count rides
    as a scalar psum (free under the HLO budget, like the loss riders).

    Returns ``(contrib', mask', prev', n_screened)``: the hardened
    buffer (every row finite, non-arriving rows exact zeros), the
    screened participation mask (⊆ ``mask``), the advanced replay buffer
    (None without one) and the GLOBAL count of rows that survived."""
    from repro.core import faults as faults_mod

    row_ids = _compress_row_ids(contrib.shape[0])
    prev_new = None
    if faults is not None:
        contrib, mask, prev_new = faults.apply(
            contrib, mask, fault_prev, round_idx, row_ids,
            payload_cols=spec.size)
    if screening is not None:
        contrib, mask = faults_mod.screen_rows(contrib, mask, screening)
    n = client_scalar_sum(jnp.ones(contrib.shape[0], jnp.float32), mask=mask)
    return contrib, mask, prev_new, n


def harden_upload_active(contrib_tile: jax.Array, active, spec, *,
                         faults=None, screening=None,
                         fault_prev: Optional[jax.Array] = None,
                         round_idx: Optional[jax.Array] = None):
    """Active-store twin of :func:`harden_upload`: faults + screening on
    the packed (capacity, N) participant tile, keyed on the tile's GLOBAL
    resident row ids (so the same clients fault as in the dense round).

    The screened rows fold back into the :class:`~repro.utils.pytree
    .ActiveSet` itself — ``valid``/``count``/dense ``mask`` all shrink to
    the surviving rows — so the unchanged
    :func:`flat_round_aggregate_active` / overlap twin aggregate exactly
    the screened set (padding AND screened-out rows are zeroed by
    ``zero_invalid``, and SCAFFOLD's ``extra_mean_tile`` rider is zeroed
    with them). The replay buffer goes through
    ``gather_state``/``scatter_state`` like the EF residual, so it rides
    the host-offloaded store's tiles unchanged. Returns
    ``(tile', active', prev', n_screened)``."""
    from repro.core import faults as faults_mod

    m_local = active.num_clients
    ids = active.idx.astype(jnp.uint32)
    if _CLIENT_AXIS is not None:
        name, _ = _CLIENT_AXIS
        ids = ids + jax.lax.axis_index(name).astype(jnp.uint32) * m_local
    ok = active.valid
    prev_new = None
    if faults is not None:
        prev_t = (active.gather_state(fault_prev)
                  if fault_prev is not None else None)
        contrib_tile, ok, prev_t_new = faults.apply(
            contrib_tile, ok, prev_t, round_idx, ids,
            payload_cols=spec.size)
        if prev_t_new is not None:
            prev_new = active.scatter_state(fault_prev, prev_t_new)
    if screening is not None:
        contrib_tile, ok = faults_mod.screen_rows(contrib_tile, ok,
                                                  screening)
    dense_ok = pt.scatter_rows(jnp.zeros((m_local,), bool), active.idx, ok)
    active2 = dataclasses.replace(
        active,
        valid=ok,
        count=jnp.sum(ok.astype(jnp.float32)),
        mask=jnp.logical_and(active.mask, dense_ok),
    )
    n = client_scalar_sum(ok.astype(jnp.float32))
    return contrib_tile, active2, prev_new, n


def per_client_value_and_grad(loss_fn: LossFn):
    """vmap(value_and_grad) over the stacked client batch, shared params."""
    vg = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])
    return jax.vmap(vg, in_axes=(None, 0))


def per_client_value_and_grad_stacked(loss_fn: LossFn):
    """vmap(value_and_grad) with PER-CLIENT params: in_axes=(0, 0).

    The async engine's stale-x̄ rounds evaluate each client's gradient at
    its own (possibly stale) anchor, so params carry the client axis too.
    On identical (broadcast) anchors this is bitwise equal to the shared
    variant above on every model in this repo (same contraction order).
    """
    vg = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])
    return jax.vmap(vg, in_axes=(0, 0))


# --------------------------------------------------------------------------
# Stale-x̄ state (async / overlapped rounds). The server still aggregates
# every round — eq. (11) stays the round's ONE model-size psum — but each
# client anchors its local branch on the x̄ it last DOWNLOADED, which may
# be up to `max_staleness` rounds old. The participation mask is the
# arrival process: mask=True means the client uploads this round (its
# contribution was computed against its stale view) and then downloads
# the current x̄. See docs/async.md for the semantics and the
# inexactness argument (arXiv:2204.10607) that licenses the staleness.
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StaleXbar:
    """Per-client stale view of the global anchor x̄ (rides in the scan carry).

    Fields (all leading-axis (m,) — sharded over the client axis like any
    `client_state_keys` entry):

    * ``anchor`` — pytree, client i's last-downloaded x̄ (its local view).
    * ``age`` — (m,) int32, rounds since client i's last download, as seen
      ENTERING a round. ``init`` sets ``max_staleness + 1`` so every
      client force-syncs at round 0 (nobody has downloaded anything yet).
    * ``last_used`` — (m,) int32, the staleness s of the anchor client i
      actually used in the round just run: its branch ran against x̄^(t-s).
      The engine reports it as the per-round ``staleness`` metric; the
      bounded-staleness invariant is ``last_used <= max_staleness``,
      always (tests/test_async.py).
    * ``max_staleness`` — static int bound. A client whose view would
      exceed it is force-refreshed BEFORE computing (the server blocks on
      over-stale clients), which is exactly why ``max_staleness=0``
      degenerates to the synchronous masked engine, bitwise.
    * ``weighting`` / ``decay`` — static staleness-aware aggregation
      schedule (see :func:`stale_weights`): how much eq. (11) downweights
      a contribution computed against an s-rounds-old anchor.
      ``"uniform"`` (default) is today's unweighted path, bitwise.
    """

    anchor: Any
    age: jax.Array
    last_used: jax.Array
    max_staleness: int = 0
    weighting: str = "uniform"
    decay: float = 1.0

    def tree_flatten(self):
        return (
            (self.anchor, self.age, self.last_used),
            (self.max_staleness, self.weighting, self.decay),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        anchor, age, last_used = children
        return cls(anchor, age, last_used, *aux)

    @property
    def always_fresh(self) -> bool:
        """Statically true when max_staleness == 0: every client refreshes
        every round, so algorithms can keep their synchronous (shared-
        anchor) gradient path — bitwise identity by construction."""
        return self.max_staleness == 0


STALE_WEIGHTINGS = ("uniform", "poly", "exp")


def init_stale_xbar(anchor, m: int, max_staleness: int,
                    weighting: str = "uniform",
                    decay: float = 1.0) -> StaleXbar:
    """Engine-side initial staleness state: the buffered view is a broadcast
    of the initial global anchor (state["x"]), and `age` starts past the
    bound so round 0 force-syncs every client to x̄⁰. `weighting`/`decay`
    select the staleness-aware aggregation schedule (`stale_weights`)."""
    if weighting not in STALE_WEIGHTINGS:
        raise ValueError(
            f"unknown stale weighting {weighting!r}: {STALE_WEIGHTINGS}"
        )
    if weighting != "uniform" and decay <= 0:
        # a negative decay would silently UPweight the stalest anchors —
        # the opposite of the documented schedule
        raise ValueError(f"stale weighting decay must be > 0, got {decay}")
    return StaleXbar(
        anchor=broadcast_clients(anchor, m),
        age=jnp.full((m,), max_staleness + 1, jnp.int32),
        last_used=jnp.zeros((m,), jnp.int32),
        max_staleness=int(max_staleness),
        weighting=weighting,
        decay=float(decay),
    )


def stale_weights(stale: Optional[StaleXbar]) -> Optional[jax.Array]:
    """Per-client aggregation weights for staleness-aware eq. (11).

    A contribution computed against an s-rounds-old anchor is one more
    bounded inexactness (arXiv:2204.10607); adaptive-aggregation results
    (arXiv:2205.02719) say to REWEIGHT it rather than average uniformly.
    Schedules (s = ``stale.last_used``, the age of the anchor the
    client's current contribution was computed against):

    * ``"uniform"`` — returns None: `client_mean` keeps its unweighted
      path, bitwise (this is why uniform weighting costs nothing).
    * ``"poly"`` — w_i = (1 + s_i)^(-decay), polynomial decay in age.
    * ``"exp"`` — w_i = exp(-decay · s_i), exponential decay in age.

    The result feeds ``client_mean(..., weights=...)``, which normalises
    by Σw (so fresh-only rounds reduce to the plain mean) and keeps
    eq. (11) a single model-size psum under sharding.
    """
    if stale is None or stale.weighting == "uniform":
        return None
    s = stale.last_used.astype(jnp.float32)
    if stale.weighting == "poly":
        return (1.0 + s) ** (-stale.decay)
    if stale.weighting == "exp":
        return jnp.exp(-stale.decay * s)
    raise ValueError(
        f"unknown stale weighting {stale.weighting!r}: {STALE_WEIGHTINGS}"
    )


def stale_xbar_view(stale: StaleXbar, xbar, mask):
    """The stale-buffer update: per-client anchor view + advanced state.

    Called once per round by every algorithm, AFTER the round's fresh x̄
    exists (for FedGiA that is eq. (11)'s aggregation — this helper is
    pure elementwise selects, so eq. (11) stays the round's one psum).

    Semantics, per client i at round t:

    1. force-sync: if ``age_i > max_staleness`` the server blocks on the
       client — it downloads x̄ᵗ before computing (bounded staleness).
    2. the round's branch runs against ``anchor_i`` (staleness
       ``s_used_i = 0`` if forced, else ``age_i`` — always
       ``<= max_staleness``).
    3. arrivals (``mask_i`` True, the arrival process) upload their
       contribution and then download x̄ᵗ: their view re-anchors, age
       resets to 1 for the next round. Non-arrivals keep their view and
       age by one more round.

    With ``max_staleness == 0`` the fresh broadcast is returned statically
    (no selects), so the lowered round is the synchronous masked round.

    Returns ``(anchor_c, stale')`` where ``anchor_c`` is the (m_local, ...)
    stacked per-client anchor and ``stale'.last_used`` records s_used.
    """
    m_local = stale.age.shape[0]
    if stale.always_fresh:
        anchor_c = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (m_local,) + l.shape), xbar
        )
        return anchor_c, StaleXbar(
            anchor_c,
            jnp.ones_like(stale.age),
            jnp.zeros_like(stale.last_used),
            0,
            stale.weighting,
            stale.decay,
        )
    force = stale.age > stale.max_staleness
    anchor_c = jax.tree.map(
        lambda buf, fresh: jnp.where(_mask_bcast(force, buf), fresh, buf),
        stale.anchor,
        xbar,
    )
    s_used = jnp.where(force, 0, stale.age).astype(jnp.int32)
    refresh = jnp.logical_or(mask, force)
    buf = jax.tree.map(
        lambda a, fresh: jnp.where(_mask_bcast(refresh, a), fresh, a),
        anchor_c,
        xbar,
    )
    age = jnp.where(refresh, 1, s_used + 1).astype(jnp.int32)
    return anchor_c, StaleXbar(buf, age, s_used, stale.max_staleness,
                               stale.weighting, stale.decay)


def stale_xbar_view_active(stale: StaleXbar, xbar, active):
    """Active-store twin of :func:`stale_xbar_view`: the anchor view is
    gathered for the packed tile only.

    The per-client SCALARS (age, last_used) stay dense (m,) — they are the
    "compact per-client riders" of the active store and advance bitwise
    like the dense path's. The resident (m, ...) anchor buffer is updated
    with one dense row-select per round (refresh rows take the fresh x̄):
    a bandwidth-only pass with NO per-client compute, which is what the
    active store actually eliminates. Returns ``(anchor_tile, stale')``
    where ``anchor_tile`` has (capacity, ...) leaves; padding rows carry a
    clamped duplicate row and are masked downstream like any tile row."""
    if stale.always_fresh:
        cap = active.capacity
        anchor_t = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (cap,) + l.shape), xbar
        )
        # the buffered view is never read while max_staleness == 0, so the
        # dense broadcast write is skipped (the dense path pays it only
        # because its round reads anchors for all m clients anyway)
        return anchor_t, StaleXbar(
            stale.anchor,
            jnp.ones_like(stale.age),
            jnp.zeros_like(stale.last_used),
            0,
            stale.weighting,
            stale.decay,
        )
    force = stale.age > stale.max_staleness
    force_t = active.gather(force)
    anchor_t = jax.tree.map(
        lambda buf, fresh: jnp.where(
            _mask_bcast(force_t, active.gather_state(buf)), fresh,
            active.gather_state(buf)
        ),
        stale.anchor,
        xbar,
    )
    s_used = jnp.where(force, 0, stale.age).astype(jnp.int32)
    refresh = jnp.logical_or(active.mask, force)
    if active.tile_state:
        # Host-offloaded store: the resident anchor lives host-side, so
        # the dense refresh write is the ENGINE's job (it applies
        # `anchor[refresh] = x̄` with the exact same row select outside
        # the jit). Return the fresh x̄ as the anchor slot so the engine
        # has its exact bits; the per-client scalars stay dense and
        # advance on-device like the active store's.
        buf = xbar
    else:
        buf = jax.tree.map(
            lambda a, fresh: jnp.where(_mask_bcast(refresh, a), fresh, a),
            stale.anchor,
            xbar,
        )
    age = jnp.where(refresh, 1, s_used + 1).astype(jnp.int32)
    return anchor_t, StaleXbar(buf, age, s_used, stale.max_staleness,
                               stale.weighting, stale.decay)


def make_algorithm(fed, loss_fn: LossFn, model=None):
    from repro.core.fedgia import FedGiA
    from repro.core.baselines.fedavg import FedAvg
    from repro.core.baselines.fedprox import FedProx
    from repro.core.baselines.fedpd import FedPD
    from repro.core.baselines.scaffold import Scaffold

    algos = {
        "fedgia": FedGiA,
        "fedavg": FedAvg,
        "fedprox": FedProx,
        "fedpd": FedPD,
        "scaffold": Scaffold,
    }
    if fed.algorithm not in algos:
        raise KeyError(f"unknown algorithm {fed.algorithm!r}: {sorted(algos)}")
    return algos[fed.algorithm](fed, loss_fn, model=model)
