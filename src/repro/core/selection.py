"""Client selection: the paper draws |C| = alpha*m clients uniformly without
replacement each communication round (§V.B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def num_selected(m: int, alpha: float) -> int:
    return max(1, min(m, int(round(alpha * m))))


def selection_mask(key, m: int, alpha: float) -> jax.Array:
    """(m,) bool — True = client runs the inexact-ADMM branch this round."""
    n_sel = num_selected(m, alpha)
    if n_sel == m:
        return jnp.ones((m,), bool)
    ranks = jax.random.permutation(key, m)
    return ranks < n_sel
