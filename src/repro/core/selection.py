"""Client participation: who runs which branch each communication round.

The paper draws |C| = alpha*m clients uniformly without replacement each
round (§V.B); selected clients run the inexact-ADMM branch (eqs. 12-14),
the rest the gradient-descent branch (eqs. 15-17). Its companion works
(arXiv 2204.10607, arXiv 2110.15318) reuse the same pattern, so the
mechanism lives at the ENGINE layer: `core/engine.py::run_rounds` folds a
`ParticipationPolicy`'s state into the `lax.scan` carry and draws a fresh
(m,) mask on device every round, which reaches `round(state, batch, mask)`
already sliced to the shard's local clients on the client-sharded path.

Masks are dense (every client's update is computed, non-participants are
masked out at the aggregation / state-combine step): on SPMD hardware this
is the only shape-stable formulation, and it is exactly how the paper's
own branch split works — see docs/engine.md.

Arrival-process view (async engine): under `run_rounds(async_rounds=True)`
the same mask is reinterpreted as WHO COMMUNICATES this round — mask=True
means the client uploads its (stale-anchored) contribution and downloads
the current x̄; mask=False means it is still offline and keeps working
against its last-downloaded x̄ (see docs/async.md). Trace-driven policies
are the natural arrival processes: `AvailabilityParticipation` replays a
measured availability trace, and `from_periods` builds the deterministic
heterogeneous-speed trace where client i arrives every p_i rounds.

The arrival process can also be CLOCK-BACKED instead of sampled:
`run_rounds(clock=...)` derives the mask from simulated per-client finish
times (core/clock.py) — a constant integer-speed clock reproduces the
`from_periods` mask sequence exactly, and generalises it to real-valued
and jittered compute times (tests/test_wallclock.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MaskAndState = Tuple[jax.Array, Any]


def num_selected(m: int, alpha: float) -> int:
    """|C| = alpha*m, clamped to [1, m] (at least one client every round)."""
    return max(1, min(m, int(round(alpha * m))))


def selection_mask(key, m: int, alpha: float) -> jax.Array:
    """(m,) bool — True = client runs the inexact-ADMM branch this round."""
    n_sel = num_selected(m, alpha)
    if n_sel == m:
        return jnp.ones((m,), bool)
    ranks = jax.random.permutation(key, m)
    return ranks < n_sel


# --------------------------------------------------------------------------
# ParticipationPolicy: a device-side per-round mask source. `init()` returns
# the policy's carry state (a pytree of arrays — it rides inside the
# engine's scan carry); `mask(pstate, round_idx)` is pure and traceable and
# returns the round's (m,) bool mask plus the advanced state. Policies must
# never return an all-False mask (num_selected clamps to >= 1; the
# availability policy falls back to full participation on dead rounds).
# --------------------------------------------------------------------------
class ParticipationPolicy:
    """Base: full participation (mask of ones), stateless."""

    name = "full"

    def __init__(self, m: int, alpha: float = 1.0):
        assert m >= 1, "need at least one client"
        self.m = m
        self.alpha = alpha

    @property
    def n_selected(self) -> int:
        return num_selected(self.m, self.alpha)

    @property
    def active_capacity(self) -> int:
        """Static upper bound on the round's participant count — the packed
        tile size of the active-set store (``run_rounds(store="active")``).

        Fixed-cardinality policies (uniform / weighted / cyclic) emit
        exactly ``n_selected`` participants every round, so the store packs
        to a (n_selected, N) tile. Variable-cardinality sources
        (availability traces, wall-clock arrivals) can select anyone, so
        their bound is m: correct, but no smaller than dense — the active
        store's memory win needs a fixed-cardinality policy.
        """
        return self.m

    def init(self) -> Any:
        return ()

    def mask(self, pstate, round_idx) -> MaskAndState:
        return jnp.ones((self.m,), bool), pstate

    def indices(self, pstate, round_idx, capacity: Optional[int] = None):
        """Active-set form of :meth:`mask`: the round's participants as a
        packed, padded index set (``pt.ActiveSet``) instead of a dense
        (m,) mask. Derived from the SAME mask draw, so the participant
        sequence is identical between the dense and active stores."""
        from repro.utils import pytree as pt

        mask, pstate = self.mask(pstate, round_idx)
        cap = self.active_capacity if capacity is None else capacity
        return pt.make_active_set(mask, cap), pstate


class UniformParticipation(ParticipationPolicy):
    """Paper §V.B: alpha*m clients uniformly without replacement per round.

    The PRNG key is the policy state: each round splits it, so the mask
    sequence is a pure function of `seed` — identical across the scan and
    legacy engine paths, and across re-runs.
    """

    name = "uniform"

    @property
    def active_capacity(self) -> int:
        return self.n_selected  # exact cardinality every round

    def __init__(self, m: int, alpha: float, seed: int = 0):
        super().__init__(m, alpha)
        self.seed = seed

    def init(self):
        return {"key": jax.random.PRNGKey(self.seed)}

    def mask(self, pstate, round_idx):
        key, sub = jax.random.split(pstate["key"])
        return selection_mask(sub, self.m, self.alpha), {"key": key}


class WeightedParticipation(ParticipationPolicy):
    """Data-size-weighted sampling without replacement (Gumbel top-k).

    `weights` are per-client sampling weights (e.g. local sample counts);
    adding Gumbel noise to log-weights and keeping the top |C| draws an
    exact weighted sample without replacement. Cardinality is always
    exactly |C| = num_selected(m, alpha).
    """

    name = "weighted"

    @property
    def active_capacity(self) -> int:
        return self.n_selected  # exact cardinality every round

    def __init__(self, m: int, alpha: float, weights, seed: int = 0):
        super().__init__(m, alpha)
        w = jnp.asarray(weights, jnp.float32)
        assert w.shape == (m,), f"weights must be (m,)={m}, got {w.shape}"
        self.log_w = jnp.log(jnp.maximum(w, 1e-30))
        self.seed = seed

    def init(self):
        return {"key": jax.random.PRNGKey(self.seed)}

    def mask(self, pstate, round_idx):
        key, sub = jax.random.split(pstate["key"])
        n_sel = self.n_selected
        if n_sel == self.m:
            return jnp.ones((self.m,), bool), {"key": key}
        z = self.log_w + jax.random.gumbel(sub, (self.m,))
        kth = jax.lax.top_k(z, n_sel)[0][-1]
        return z >= kth, {"key": key}


class CyclicParticipation(ParticipationPolicy):
    """Deterministic round-robin blocks of |C| clients: round t selects
    clients [t*|C|, t*|C| + |C|) mod m — every client participates exactly
    once per ceil(m/|C|)-round cycle (up to wrap-around overlap). Useful as
    a variance-free scenario and for reproducible stragglers."""

    name = "cyclic"

    @property
    def active_capacity(self) -> int:
        return self.n_selected  # exact cardinality every round

    def init(self):
        return ()

    def mask(self, pstate, round_idx):
        n_sel = self.n_selected
        start = (jnp.asarray(round_idx, jnp.int32) * n_sel) % self.m
        offset = (jnp.arange(self.m, dtype=jnp.int32) - start) % self.m
        return offset < n_sel, pstate


class AvailabilityParticipation(ParticipationPolicy):
    """Replay a (T, m) bool availability trace (heterogeneous-client /
    straggler scenario): round t uses row t mod T. A row with no available
    client falls back to full participation so aggregation never divides
    by zero (in the async arrival reading: an idle server round syncs
    everyone). `alpha` is not used (cardinality varies per round).

    Under the async engine the trace IS the arrival process: trace[t, i]
    is "client i communicates at round t". Between two True entries the
    client's staleness grows one round per row (capped by the engine's
    `max_staleness` forced sync) — so a measured availability trace
    directly induces the staleness distribution the stale-x̄ variant is
    exposed to.
    """

    name = "availability"

    def __init__(self, m: int, trace):
        super().__init__(m, alpha=1.0)
        tr = jnp.asarray(trace, bool)
        assert tr.ndim == 2 and tr.shape[1] == m, (
            f"trace must be (T, m={m}), got {tr.shape}"
        )
        self.trace = tr

    @classmethod
    def from_dropout(cls, m: int, drop_prob: float, horizon: int,
                     seed: int = 0) -> "AvailabilityParticipation":
        """iid straggler dropout: each client independently unavailable
        with probability `drop_prob` each round, frozen into a trace so
        runs are reproducible and the mask draw costs one gather."""
        rng = np.random.default_rng(seed)
        trace = rng.random((horizon, m)) >= drop_prob
        return cls(m, trace)

    @classmethod
    def from_periods(cls, m: int, periods, horizon: int = 256
                     ) -> "AvailabilityParticipation":
        """Deterministic heterogeneous-speed arrivals: client i
        communicates every `periods[i]` rounds (first arrival at round 0,
        so every client starts synchronized). This is the variance-free
        arrival process for the async engine — after the round-0 sync,
        client i's staleness cycles 1, ..., p_i deterministically (capped
        by the engine's max_staleness force-sync; even a period-1 client
        carries the one-round pipeline delay of computing while the
        server aggregates) — and the reference scenario of
        benchmarks/async_bench.py. `horizon` must cover the run (the
        trace replays modulo its length, which breaks periodicity for
        p_i that do not divide it)."""
        p = np.asarray(periods, np.int64)
        assert p.shape == (m,), f"periods must be (m={m},), got {p.shape}"
        assert (p >= 1).all(), f"periods must be >= 1, got {p}"
        t = np.arange(horizon)[:, None]
        return cls(m, (t % p[None, :]) == 0)

    def init(self):
        return ()

    def mask(self, pstate, round_idx):
        t = jnp.asarray(round_idx, jnp.int32) % self.trace.shape[0]
        row = jnp.take(self.trace, t, axis=0)
        return jnp.where(row.any(), row, jnp.ones_like(row)), pstate


POLICIES = ("full", "uniform", "weighted", "cyclic", "straggler", "periodic")


def make_policy(
    kind: str,
    m: int,
    alpha: float = 1.0,
    *,
    seed: int = 0,
    weights=None,
    drop_prob: float = 0.2,
    horizon: int = 256,
    periods=None,
) -> Optional[ParticipationPolicy]:
    """CLI-level factory. `kind="full"` returns None: the engine then runs
    the legacy in-algorithm path (FedGiA keeps its internal §V.B draw,
    baselines run full participation) — byte-compatible with pre-mask runs.

    `kind="periodic"` builds the deterministic heterogeneous-speed arrival
    process (`from_periods`); `periods` defaults to speeds cycling 1..4
    rounds across clients (launch: --arrival-periods for explicit ones).
    """
    if kind == "full":
        return None
    if kind == "uniform":
        return UniformParticipation(m, alpha, seed=seed)
    if kind == "weighted":
        if weights is None:
            # equal weights = uniform sampling; pass real per-client data
            # sizes (launch: --client-weights) for the weighted scenario
            weights = jnp.ones((m,), jnp.float32)
        return WeightedParticipation(m, alpha, weights, seed=seed)
    if kind == "cyclic":
        return CyclicParticipation(m, alpha)
    if kind == "straggler":
        return AvailabilityParticipation.from_dropout(
            m, drop_prob, horizon, seed=seed
        )
    if kind == "periodic":
        if periods is None:
            periods = 1 + (np.arange(m) % 4)
        return AvailabilityParticipation.from_periods(m, periods, horizon)
    raise KeyError(f"unknown participation policy {kind!r}: {POLICIES}")
