"""Uplink compression for eq. (11)'s flat communication buffer.

FedGiA's headline claim is communication efficiency, but the engine's
eq. (11) aggregation moves full-precision flat buffers: every round each
participating client uploads its (N,) contribution (FedGiA: z_i, the
baselines: their local trajectory) in fp32. This module adds the
compressed-FL recipe of arXiv:2205.02719 on top of the PR-5 flat layout:
the uplink is quantized (`bf16`, `int8` with stochastic rounding) or
sparsified (`topk`), optionally with PER-CLIENT ERROR FEEDBACK — the
residual e_i = u_i - C(u_i) of each round's codec error is carried
client-side and added to the next upload, so the compression error
telescopes instead of accumulating (the inexact-ADMM analysis of
arXiv:2110.15318 is exactly the licence FedGiA already exploits for its
inexact local solves).

Design constraints, in order:

* **decompress-before-reduce** — codecs are pure encode+decode round
  trips on the (rows, N) buffer: the server-visible value C(u_i) is
  computed CLIENT-SIDE (shard-local under client sharding) and the fp32
  decode is what enters the round's ONE model-size psum. The collective
  structure of the round is untouched, so the one-all-reduce HLO
  invariant of the sharded flat round holds for every codec
  (tests/test_compress.py asserts it).
* **bitwise `none` escape** — the identity codec never touches the round
  path at all: the engine resolves ``compression="none"`` (without error
  feedback) to "no compressor", so the lowered round is THE SAME program,
  not an equal one. The codec object still models the uncompressed wire
  size for the byte-accurate clock.
* **zero-tail preservation** — the wire format carries the ``n`` LOGICAL
  lanes only; the lane-padded tail of the flat buffer never leaves the
  client, and `api.compress_upload` re-zeros it after decode, so the
  RavelSpec zero-tail invariant (norms, Pallas kernel) survives lossy
  codecs whose decode of 0 is not exactly 0 (affine int8).

Wire-byte model (`wire_bytes`): one upload = a fixed per-message
``HEADER_BYTES`` (framing: client id, round, codec tag) + the payload.
``none`` 4n, ``bf16`` 2n, ``int8`` n + 8 (per-row affine scale +
zero-point, fp32 each), ``topk`` 8k (4-byte lane index + 4-byte fp32
value per kept lane). The byte-accurate clock (core/clock.py,
``bandwidth_bps``) turns these into per-client comm seconds so the
wallclock bench can show compression buying time-to-target, not just
fewer bits (BENCH_wallclock's compression section).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# Fixed per-upload framing overhead (client id, round index, codec tag).
HEADER_BYTES = 8

COMPRESSORS = ("none", "bf16", "int8", "topk")


def round_key(rng: jax.Array, round_idx: jax.Array) -> jax.Array:
    """The round's stochastic-rounding base key: fold the round counter
    into the algorithm's rng WITHOUT advancing its stream (the selection
    split stays bitwise whatever the codec). Replicated across shards —
    `api.compress_upload` derives per-client keys from global row ids, so
    sharded and unsharded rounds draw identical per-client noise."""
    return jax.random.fold_in(rng, round_idx)


class Compressor:
    """Base codec: a pure encode+decode round trip on a (rows, N) buffer.

    ``error_feedback`` marks whether the engine should carry the
    per-client residual buffer (``state["ef"]``, one extra (m, N) flat
    `flat_client_keys` entry) and `api.compress_upload` should fold it
    into the upload. ``stochastic`` codecs receive per-row PRNG keys.

    Because the residual is declared through `flat_client_keys`, it
    rides every client-state store for free: packed to a (capacity, N)
    tile under ``store="active"`` and resident in HOST memory under
    ``store="offload"`` (gathered/advanced/scattered through the same
    host rows as any client buffer — docs/scaling.md). Stochastic
    rounding keys come from global row ids, so the draw is identical in
    all three stores.
    """

    name = "abstract"
    stochastic = False

    def __init__(self, error_feedback: bool = False):
        self.error_feedback = bool(error_feedback)

    @property
    def identity(self) -> bool:
        """True when decode(encode(u)) == u bitwise for every u — the
        engine drops identity codecs (without error feedback) from the
        round path entirely, keeping ``compression="none"`` the SAME
        lowered program."""
        return False

    def encode_decode(self, u: jax.Array, *, keys: Optional[jax.Array] = None,
                      n: Optional[int] = None) -> jax.Array:
        """The server-visible decode of one upload per row of ``u``.

        ``keys`` — (rows,) stacked PRNG keys (stochastic codecs only).
        ``n`` — the LOGICAL lane count (``spec.size``); buffers arrive
        lane-padded and codecs that size their payload from the model
        dimension (top-k) must not count padding lanes.
        """
        raise NotImplementedError

    def wire_bytes(self, n: int) -> int:
        """Exact uplink bytes for one client's upload of n logical lanes
        (header + payload — the padded tail is never transmitted)."""
        raise NotImplementedError

    def __repr__(self):
        ef = ", error_feedback=True" if self.error_feedback else ""
        return f"{type(self).__name__}({self.name!r}{ef})"


class NoneCompressor(Compressor):
    """Bitwise identity escape: full-precision fp32 uplink. Exists so the
    byte clock can price the UNCOMPRESSED wire; the engine never routes
    round math through it."""

    name = "none"

    @property
    def identity(self) -> bool:
        return True

    def encode_decode(self, u, *, keys=None, n=None):
        return u

    def wire_bytes(self, n: int) -> int:
        return HEADER_BYTES + 4 * n


class Bf16Compressor(Compressor):
    """bfloat16 quantization: keep fp32's 8-bit exponent, drop the
    mantissa to 7 bits — 2 bytes/lane. ``rounding="nearest"`` is the
    round-to-nearest-even cast; ``"stochastic"`` adds uniform noise in
    the truncated 16 mantissa bits before truncating, making the decode
    unbiased (E[C(u)] = u) at the cost of ~2x the nearest-rounding error.
    Values already representable in bf16 (zeros included — the padded
    tail) round-trip exactly under both modes."""

    name = "bf16"

    def __init__(self, error_feedback: bool = False,
                 rounding: str = "nearest"):
        super().__init__(error_feedback)
        if rounding not in ("nearest", "stochastic"):
            raise ValueError(
                f"bf16 rounding must be 'nearest' or 'stochastic', "
                f"got {rounding!r}")
        self.rounding = rounding

    @property
    def stochastic(self) -> bool:
        return self.rounding == "stochastic"

    def encode_decode(self, u, *, keys=None, n=None):
        if self.rounding == "nearest":
            return u.astype(jnp.bfloat16).astype(u.dtype)
        # stochastic: add uniform bits in [0, 2^16) to the fp32 bit
        # pattern, then truncate the low 16 bits — unbiased within the
        # bf16 lattice. Exact bf16 values (bit pattern with a zero low
        # half) stay exact: noise < 2^16 never carries into the kept bits
        # ... unless the value already has nonzero low bits, which is the
        # point. Requires an fp32 buffer (the flat spec dtype).
        assert keys is not None, "stochastic bf16 needs per-row keys"
        bits = jax.lax.bitcast_convert_type(u.astype(jnp.float32),
                                            jnp.uint32)
        noise = jax.vmap(
            lambda k: jax.random.randint(
                k, u.shape[1:], 0, 1 << 16, dtype=jnp.uint32)
        )(keys)
        out = jax.lax.bitcast_convert_type(
            (bits + noise) & jnp.uint32(0xFFFF0000), jnp.float32)
        return out.astype(u.dtype)

    def wire_bytes(self, n: int) -> int:
        return HEADER_BYTES + 2 * n


class Int8Compressor(Compressor):
    """Per-row affine 8-bit quantization: each client's upload is mapped
    onto a 256-level grid between its row minimum (the zero-point) and
    maximum, q = round((u - lo)/scale) in [0, 255], decode lo + q*scale
    — 1 byte/lane + the two fp32 row constants on the wire. The decode
    error is bounded by the grid: |u - C(u)| <= scale/2 under nearest
    rounding, < scale under stochastic rounding (floor(t + U[0,1)),
    which is unbiased: E[C(u)] = u). A constant row (scale 0) decodes
    exactly."""

    name = "int8"

    def __init__(self, error_feedback: bool = False,
                 rounding: str = "stochastic"):
        super().__init__(error_feedback)
        if rounding not in ("nearest", "stochastic"):
            raise ValueError(
                f"int8 rounding must be 'nearest' or 'stochastic', "
                f"got {rounding!r}")
        self.rounding = rounding

    @property
    def stochastic(self) -> bool:
        return self.rounding == "stochastic"

    def encode_decode(self, u, *, keys=None, n=None):
        f = u.astype(jnp.float32)
        lo = jnp.min(f, axis=-1, keepdims=True)
        hi = jnp.max(f, axis=-1, keepdims=True)
        scale = (hi - lo) / 255.0
        safe = jnp.where(scale > 0, scale, 1.0)
        t = (f - lo) / safe
        if self.rounding == "stochastic":
            assert keys is not None, "stochastic int8 needs per-row keys"
            noise = jax.vmap(
                lambda k: jax.random.uniform(k, u.shape[1:], jnp.float32)
            )(keys)
            q = jnp.floor(t + noise)
        else:
            q = jnp.round(t)
        q = jnp.clip(q, 0.0, 255.0)
        dec = lo + q * jnp.where(scale > 0, safe, 0.0)
        return dec.astype(u.dtype)

    def wire_bytes(self, n: int) -> int:
        return HEADER_BYTES + 8 + n  # fp32 scale + zero-point, 1B/lane


class TopKCompressor(Compressor):
    """Magnitude top-k sparsification: each row keeps its k largest-|·|
    lanes exactly (fp32) and zeroes the rest; the wire carries k
    (index, value) pairs. k = max(1, round(frac * n)) over the LOGICAL
    lane count — padding lanes are never counted (and a padded-tail zero
    can only be "kept" when a row has fewer than k nonzeros, where it
    decodes to exactly 0 anyway). Deterministic: ties break by lane
    order (`jax.lax.top_k`). Top-k is the codec that NEEDS error
    feedback — dropped lanes carry over instead of being lost."""

    name = "topk"

    def __init__(self, frac: float = 0.1, error_feedback: bool = False):
        super().__init__(error_feedback)
        if not (0.0 < frac <= 1.0):
            raise ValueError(f"topk frac must be in (0, 1], got {frac}")
        self.frac = float(frac)

    def k_for(self, n: int) -> int:
        return max(1, min(n, int(round(self.frac * n))))

    def encode_decode(self, u, *, keys=None, n=None):
        k = self.k_for(n if n is not None else u.shape[-1])
        flat = u.reshape((-1, u.shape[-1]))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = jnp.take_along_axis(flat, idx, axis=-1)
        rows = jnp.arange(flat.shape[0], dtype=idx.dtype)[:, None]
        dec = jnp.zeros_like(flat).at[rows, idx].set(vals)
        return dec.reshape(u.shape)

    def wire_bytes(self, n: int) -> int:
        return HEADER_BYTES + 8 * self.k_for(n)  # 4B index + 4B value


def downlink_bytes(n: int) -> int:
    """Per-client download of the fresh x̄: full-precision fp32 (the
    server broadcast is NOT compressed — error feedback has no client-side
    twin for the downlink in this recipe)."""
    return HEADER_BYTES + 4 * n


def uplink_bytes(compressor: Optional[Compressor], n: int) -> int:
    """Per-client upload bytes under `compressor` (None = raw fp32)."""
    if compressor is None:
        return NoneCompressor().wire_bytes(n)
    return compressor.wire_bytes(n)


def make_compressor(name: str, *, error_feedback: bool = False,
                    topk_frac: float = 0.1,
                    rounding: Optional[str] = None) -> Compressor:
    """CLI-level factory (`run_rounds(compression=...)`,
    `train.py --compression`). ``rounding=None`` keeps each codec's
    default (bf16: nearest, int8: stochastic)."""
    if name == "none":
        if error_feedback:
            raise ValueError(
                "error feedback with the identity codec is a residual "
                "that is always zero — drop --error-feedback or pick a "
                "lossy codec (bf16/int8/topk)")
        return NoneCompressor()
    if name == "bf16":
        kw = {} if rounding is None else {"rounding": rounding}
        return Bf16Compressor(error_feedback, **kw)
    if name == "int8":
        kw = {} if rounding is None else {"rounding": rounding}
        return Int8Compressor(error_feedback, **kw)
    if name == "topk":
        return TopKCompressor(topk_frac, error_feedback)
    raise KeyError(f"unknown compression {name!r}: {COMPRESSORS}")


def as_compressor(compression, *, error_feedback: bool = False,
                  topk_frac: float = 0.1) -> Optional[Compressor]:
    """Engine-boundary resolution: None passes through, a string goes
    through `make_compressor`, a `Compressor` instance is used as-is
    (``error_feedback``/``topk_frac`` then live on the instance)."""
    if compression is None:
        if error_feedback:
            raise ValueError(
                "error_feedback=True needs a lossy compression codec "
                "(bf16/int8/topk)")
        return None
    if isinstance(compression, Compressor):
        return compression
    return make_compressor(compression, error_feedback=error_feedback,
                           topk_frac=topk_frac)
