"""Shared pieces for the paper's comparison baselines (§V.D).

All baselines use the paper's learning-rate schedule
    gamma_k(a) = a / log2(k + 2)
with k the GLOBAL inner-iteration counter. The paper's comparison protocol
is full participation (all m clients update every step); the engine can
instead pass a per-round participation mask (core/selection.py), in which
case only masked-in clients contribute to the aggregation and per-client
state of masked-out clients is frozen.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core.api import broadcast_clients, per_client_value_and_grad
from repro.utils import pytree as pt


def lr_schedule(a, k):
    return a / (jnp.log2(k.astype(jnp.float32) + 2.0))


def round_metrics(losses, grads, round_idx, mask=None):
    # cross-client reductions go through the api helpers so the same
    # metrics are exact when the engine shards the client axis. Loss and
    # grad-norm stay ALL-client means (global objective diagnostics, same
    # quantity whatever the participation); `selected` reports the round's
    # participant count.
    gmean = api.client_mean(grads)
    return {
        "f_xbar": api.client_scalar_mean(losses),
        "grad_sq_norm": pt.tree_sq_norm(gmean),
        "selected": api.client_scalar_sum(jnp.ones_like(losses), mask=mask),
        "cr": 2.0 * (round_idx + 1).astype(jnp.float32),
    }
