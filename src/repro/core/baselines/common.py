"""Shared pieces for the paper's comparison baselines (§V.D).

All baselines use the paper's learning-rate schedule
    gamma_k(a) = a / log2(k + 2)
with k the GLOBAL inner-iteration counter. The paper's comparison protocol
is full participation (all m clients update every step); the engine can
instead pass a per-round participation mask (core/selection.py), in which
case only masked-in clients contribute to the aggregation and per-client
state of masked-out clients is frozen.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import api, compress
from repro.core.api import broadcast_clients, per_client_value_and_grad
from repro.utils import pytree as pt


def lr_schedule(a, k):
    return a / (jnp.log2(k.astype(jnp.float32) + 2.0))


def round_metrics(losses, grads, round_idx, mask=None):
    # cross-client reductions go through the api helpers so the same
    # metrics are exact when the engine shards the client axis. Loss and
    # grad-norm stay ALL-client means (global objective diagnostics, same
    # quantity whatever the participation); `selected` reports the round's
    # participant count.
    gmean = api.client_mean(grads)
    return {
        "f_xbar": api.client_scalar_mean(losses),
        "grad_sq_norm": pt.tree_sq_norm(gmean),
        "selected": api.client_scalar_sum(jnp.ones_like(losses), mask=mask),
        "cr": 2.0 * (round_idx + 1).astype(jnp.float32),
    }


# ------------------------------------------------------------- flat buffer
def flat_value_and_grad(vg_stacked, spec):
    """Route a stacked value-and-grad through the flat (m, N) view.

    The baselines' local GD loops carry their per-client trajectories as
    one contiguous (m, N) buffer (engine `flat=True`); the loss is still
    a pytree function of the model, so each gradient evaluation unravels
    the buffer, evaluates, and ravels the gradients back — the ONLY
    pytree boundary in the local loop. An unravel->ravel round trip is
    exact (RavelSpec casts to a wider-or-equal dtype), so the flat local
    steps are bitwise the pytree local steps on the raveled layout."""

    def fvg(x_flat, batch):
        losses, grads = vg_stacked(spec.unravel_stacked(x_flat), batch)
        return losses, spec.ravel_stacked(grads)

    return fvg


def participation_vec(losses, mask):
    """The (m_local,) `selected`-metric indicator: 1 for participants, 0
    for masked-out clients (matches `client_scalar_sum(ones, mask=...)`
    bitwise)."""
    ones = jnp.ones_like(losses)
    return ones if mask is None else jnp.where(mask, ones, 0)


def round_metrics_flat(gsq, f_mean, n_sel, round_idx):
    """`round_metrics` from the outputs of `api.flat_round_aggregate` (the
    flat rounds compute the reductions fused with eq. (11)'s psum)."""
    return {
        "f_xbar": f_mean,
        "grad_sq_norm": gsq,
        "selected": n_sel,
        "cr": 2.0 * (round_idx + 1).astype(jnp.float32),
    }


# ------------------------------------------------------------- compression
def compress_contrib(compressor, state, contrib, spec, mask=None):
    """The baselines' uplink hook (core/compress.py): the (m, N) round
    contribution through the codec, just before `api.flat_round_aggregate`
    — returns ``(decoded, ef')``, ``(contrib, None)`` when uncompressed.
    Error-feedback residuals come from/advance ``state["ef"]`` (created
    by the engine); the stochastic-rounding key folds the round counter
    into the algorithm's rng WITHOUT advancing its stream, so selection
    stays bitwise whatever the codec. With ``mask``, frozen clients keep
    their residual (they did not upload this round)."""
    if compressor is None:
        return contrib, None
    ef = state.get("ef") if compressor.error_feedback else None
    key = compress.round_key(state["rng"], state["round"])
    return api.compress_upload(compressor, contrib, ef, spec,
                               key=key, mask=mask)


def compress_contrib_active(compressor, state, contrib_tile, spec, active):
    """Active-store twin of `compress_contrib`: the codec runs on the
    packed (capacity, N) participant tile (`api.compress_upload_active`);
    the returned ``ef'`` is the full dense residual with non-participant
    rows untouched."""
    if compressor is None:
        return contrib_tile, None
    ef = state.get("ef") if compressor.error_feedback else None
    key = compress.round_key(state["rng"], state["round"])
    return api.compress_upload_active(compressor, contrib_tile, ef,
                                      active, spec, key=key)
