"""SCAFFOLD [Karimireddy et al. 2020] — stochastic controlled averaging
with client/server control variates (paper Table I comparison set).

Local: y ← y − lr (∇f_i(y) − c_i + c), k0 steps.
Control update (option II): c_i⁺ = c_i − c + (x̄ − y)/(k0·lr).
Server: x̄ += mean(y − x̄);  c += mean(c_i⁺ − c_i).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core import api
from repro.core.api import LossFn, broadcast_clients
from repro.core.baselines.common import (
    compress_contrib,
    compress_contrib_active,
    flat_value_and_grad,
    lr_schedule,
    participation_vec,
    round_metrics,
    round_metrics_flat,
)
from repro.utils import pytree as pt


class Scaffold:
    name = "scaffold"
    # "ef" = compression error-feedback residual (core/compress.py) and
    # "fault_prev" = the fault model's replay buffer (core/faults.py);
    # present only when the engine enables them — absent keys cost nothing
    client_state_keys = ("ci", "ef", "fault_prev")
    flat_client_keys = ("ci", "ef", "fault_prev")
    flat_global_keys = ("x", "c")
    active_tile = "participants"  # frozen clients keep their control variates
    # overlapped rounds defer TWO means across the round boundary: the
    # server model mean(y) and the control-variate delta mean(ci⁺ − ci) —
    # both ride the one reduce-scatter as stacked rows (engine slot seed)
    overlap_slot_rows = 2

    def __init__(self, fed: FedConfig, loss_fn: LossFn, model=None):
        self.fed = fed
        self.loss_fn = loss_fn
        self.model = model
        self._vg_stacked = api.per_client_value_and_grad_stacked(loss_fn)

    def init(self, params0, rng, init_batch=None):
        sdt = jnp.dtype(self.fed.state_dtype)
        m = self.fed.num_clients
        x = pt.tree_cast(params0, sdt)
        stacked = broadcast_clients(x, m)
        return {
            "x": x,
            "c": pt.tree_zeros_like(x),
            "ci": pt.tree_zeros_like(stacked),
            "round": jnp.zeros((), jnp.int32),
            "step": jnp.zeros((), jnp.int32),
            "rng": rng,
        }

    def round(self, state, batch, mask=None, stale=None):
        fed = self.fed
        m = api.local_client_count(fed.num_clients)
        # stale-x̄ rounds: local steps start from (and the option-II control
        # update measures drift against) the client's last-downloaded
        # anchor; bitwise-fresh when max_staleness=0.
        if stale is None:
            xc = broadcast_clients(state["x"], m)
        else:
            xc, stale = api.stale_xbar_view(stale, state["x"], mask)
        lr = lr_schedule(fed.lr, state["step"])

        vg = self._vg_stacked

        def local_step(carry, j):
            y, first = carry
            losses, grads = vg(y, batch)
            lr_j = lr_schedule(fed.lr, state["step"] + j)
            y_new = jax.tree.map(
                lambda p, g, cc, ci: p - lr_j * (g + cc[None] - ci).astype(p.dtype),
                y,
                grads,
                state["c"],
                state["ci"],
            )
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f), first, (losses, grads)
            )
            return (y_new, first), None

        first0 = (jnp.zeros((m,), jnp.float32), pt.tree_zeros_like(xc))
        (y, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )

        denom = fed.k0 * lr
        # drift is measured against the anchor the client actually started
        # from (xc == broadcast of the fresh x̄ in synchronous rounds)
        ci_new = jax.tree.map(
            lambda ci, cc, a, yy: ci - cc[None] + (a - yy) / denom,
            state["ci"],
            state["c"],
            xc,
            y,
        )
        # partial participation (SCAFFOLD §4): frozen clients keep their
        # control variates; the server model averages participants only,
        # while c's update keeps the all-client 1/N denominator — frozen
        # clients contribute a zero delta, giving the paper's |S|/N scaling.
        if mask is not None:
            ci_new = api.masked_update(mask, ci_new, state["ci"])
        # staleness-aware weights downweight trajectories run from an old
        # anchor (None = uniform = bitwise unweighted); the control-variate
        # mean below keeps the paper's uniform 1/N scaling regardless —
        # the variates CORRECT drift, they are not model mass to reweight
        x_new = api.client_mean(y, mask=mask,
                                weights=api.stale_weights(stale))
        c_new = pt.tree_add(
            state["c"],
            api.client_mean(pt.tree_sub(ci_new, state["ci"])),
        )

        new_state = dict(state)
        new_state.update(
            x=x_new,
            c=c_new,
            ci=ci_new,
            round=state["round"] + 1,
            step=state["step"] + fed.k0,
        )
        metrics = round_metrics(losses0, grads0, state["round"], mask=mask)
        metrics["local_grad_evals"] = jnp.float32(fed.k0)
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ------------------------------------------------------------ flat round
    def round_flat(self, state, batch, spec, mask=None, stale=None,
                   compressor=None, donate_kernel=False,
                   faults=None, screening=None):
        """`round` on the flat (m, N) buffers: trajectories and control
        variates are contiguous arrays, and the server-model mean, the
        control-variate delta mean AND the diagnostics all ride eq. (11)'s
        ONE fused reduction (`extra_mean=` in `api.flat_round_aggregate`)
        — the pytree round needs three model-size all-reduces for the
        same quantities under sharding. `compressor` encodes the uploaded
        trajectory y only; the control-variate delta rides uncompressed
        (the wire-byte model charges one model-size upload per client —
        docs/compression.md spells out the approximation).

        Overlap (engine-seeded 2-row `state["ovl_shard"]`): the round
        all-gathers BOTH pending means at the top — row 0 the anchor
        mean(y), row 1 the control-variate delta mean, so this round's
        server variate is `c_used = state["c"] + cons[1]` (exactly the
        barrier's c for the same round; row 1 seeds to zeros, matching
        round 0's c) — and reduce-scatters this round's two means at the
        end. `state["c"]` stores `c_used` (lagging one delta, like x; the
        `overlap_finalize` hook folds the pending rows in at run end).
        `donate_kernel` is accepted for round-fn uniformity and ignored.
        """
        fed = self.fed
        m = api.local_client_count(fed.num_clients)
        ovl = state.get("ovl_shard")
        if ovl is None:
            anchor_x, c_used = state["x"], state["c"]
        else:
            cons = api.flat_overlap_consensus(ovl)
            anchor_x = cons[0]
            c_used = state["c"] + cons[1]
        if stale is None:
            xc = broadcast_clients(anchor_x, m)
        else:
            xc, stale = api.stale_xbar_view(stale, anchor_x, mask)
        lr = lr_schedule(fed.lr, state["step"])
        fvg = flat_value_and_grad(self._vg_stacked, spec)

        def local_step(carry, j):
            y, first = carry
            losses, grads = fvg(y, batch)
            lr_j = lr_schedule(fed.lr, state["step"] + j)
            y_new = y - lr_j * (grads + c_used[None]
                                - state["ci"]).astype(y.dtype)
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f), first,
                (losses, grads)
            )
            return (y_new, first), None

        first0 = (jnp.zeros((m,), jnp.float32), jnp.zeros_like(xc))
        (y, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )

        denom = fed.k0 * lr
        ci_new = state["ci"] - c_used[None] + (xc - y) / denom
        if mask is not None:
            ci_new = api.masked_update(mask, ci_new, state["ci"])
        y_up, ef_new = compress_contrib(compressor, state, y, spec, mask=mask)
        hardened = faults is not None or screening is not None
        fprev_new = None
        dmean = ci_new - state["ci"]
        if hardened:
            y_up, mask, fprev_new, n_scr = api.harden_upload(
                y_up, mask, spec, faults=faults, screening=screening,
                fault_prev=state.get("fault_prev"),
                round_idx=state["round"])
            # a rejected/lost upload drops the client's control-variate
            # delta with it (the client still advanced its local ci —
            # the server just never saw this round's delta)
            dmean = jnp.where(mask[:, None], dmean, jnp.zeros_like(dmean))
        if ovl is None:
            x_new, gsq, f_mean, n_sel, dci = api.flat_round_aggregate(
                y_up, grads0, losses0, participation_vec(losses0, mask),
                spec, mask=mask, weights=api.stale_weights(stale),
                extra_mean=dmean,
            )
            x_new_out, c_new = x_new, state["c"] + dci
        else:
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate(
                y_up, grads0, losses0, participation_vec(losses0, mask),
                spec, mask=mask, weights=api.stale_weights(stale),
                extra_mean=dmean,
            )
            x_new_out, c_new = anchor_x, c_used

        new_state = dict(state)
        new_state.update(
            x=x_new_out,
            c=c_new,
            ci=ci_new,
            round=state["round"] + 1,
            step=state["step"] + fed.k0,
        )
        if ovl is not None:
            new_state["ovl_shard"] = slot
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        metrics = round_metrics_flat(gsq, f_mean, n_sel, state["round"])
        metrics["local_grad_evals"] = jnp.float32(fed.k0)
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # --------------------------------------------------------------- overlap
    def overlap_finalize(self, state, slot):
        """Engine hook closing an overlapped run: fold the pending
        reduce-scattered means in — row 0 is the final server model, row 1
        the last round's control-variate delta."""
        state["x"] = slot[0]
        state["c"] = state["c"] + slot[1]
        return state

    # ----------------------------------------------------- active-set round
    def round_flat_active(self, state, batch, spec, active, stale=None,
                          compressor=None, donate_kernel=False,
                          faults=None, screening=None):
        """`round_flat` on the packed participant tile (store="active"):
        participant control variates are GATHERED from the resident (m, N)
        `ci` buffer, advanced on the (capacity, N) tile, and SCATTERED back
        (frozen rows untouched == the dense `masked_update` freeze). The
        server variate keeps the paper's all-client 1/N denominator: the
        tile's delta sum equals the dense delta sum because frozen clients'
        deltas are exactly zero, so dividing the packed sum by the GLOBAL
        client count (`extra_mean_tile=`) reproduces the |S|/N scaling
        bitwise."""
        fed = self.fed
        cap = active.capacity
        batch_t = active.gather_tree(batch)
        ovl = state.get("ovl_shard")
        if ovl is None:
            anchor_x, c_used = state["x"], state["c"]
        else:
            cons = api.flat_overlap_consensus(ovl)
            anchor_x = cons[0]
            c_used = state["c"] + cons[1]
        if stale is None:
            xc = broadcast_clients(anchor_x, cap)
        else:
            xc, stale = api.stale_xbar_view_active(stale, anchor_x, active)
        lr = lr_schedule(fed.lr, state["step"])
        ci_t = active.gather_state(state["ci"])
        fvg = flat_value_and_grad(self._vg_stacked, spec)

        def local_step(carry, j):
            y, first = carry
            losses, grads = fvg(y, batch_t)
            lr_j = lr_schedule(fed.lr, state["step"] + j)
            y_new = y - lr_j * (grads + c_used[None] - ci_t).astype(y.dtype)
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f), first,
                (losses, grads)
            )
            return (y_new, first), None

        first0 = (jnp.zeros((cap,), jnp.float32), jnp.zeros_like(xc))
        (y, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )

        denom = fed.k0 * lr
        ci_new_t = ci_t - c_used[None] + (xc - y) / denom
        ci_new = active.scatter_state(state["ci"], ci_new_t)
        w = api.stale_weights(stale)
        y_up, ef_new = compress_contrib_active(compressor, state, y, spec,
                                               active)
        hardened = faults is not None or screening is not None
        fprev_new = None
        if hardened:
            # the hardened ActiveSet's shrunk `valid` zeroes the screened
            # rows out of the extra_mean_tile rider inside the aggregate
            y_up, active, fprev_new, n_scr = api.harden_upload_active(
                y_up, active, spec, faults=faults, screening=screening,
                fault_prev=state.get("fault_prev"),
                round_idx=state["round"])
        if ovl is None:
            x_new, gsq, f_mean, n_sel, dci = api.flat_round_aggregate_active(
                y_up, grads0, losses0, active, spec,
                weights=w,
                extra_mean_tile=ci_new_t - ci_t,
            )
            x_new_out, c_new = x_new, state["c"] + dci
        else:
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate_active(
                y_up, grads0, losses0, active, spec,
                weights=w,
                extra_mean_tile=ci_new_t - ci_t,
            )
            x_new_out, c_new = anchor_x, c_used

        new_state = dict(state)
        new_state.update(
            x=x_new_out,
            c=c_new,
            ci=ci_new,
            round=state["round"] + 1,
            step=state["step"] + fed.k0,
        )
        if ovl is not None:
            new_state["ovl_shard"] = slot
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        metrics = round_metrics_flat(gsq, f_mean, n_sel, state["round"])
        metrics["local_grad_evals"] = jnp.float32(fed.k0)
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics
