"""FedAvg [McMahan et al. 2017] — the paper's §V.D non-stochastic version:
every client runs k0 full-batch GD steps between aggregations.

Per-round local cost: k0 GRADIENT evaluations per client (vs FedGiA's one)
— the computational-efficiency comparison of paper Table I is directly
visible in the lowered HLO FLOPs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core import api
from repro.core.api import LossFn, broadcast_clients
from repro.core.baselines.common import (
    compress_contrib,
    compress_contrib_active,
    flat_value_and_grad,
    lr_schedule,
    participation_vec,
    round_metrics,
    round_metrics_flat,
)
from repro.utils import pytree as pt


class FedAvg:
    name = "fedavg"
    # "ef" = compression error-feedback residual (core/compress.py) and
    # "fault_prev" = the fault model's replay buffer (core/faults.py);
    # present only when the engine enables them — absent keys cost nothing
    client_state_keys = ("ef", "fault_prev")
    flat_client_keys = ("ef", "fault_prev")
    flat_global_keys = ("x",)
    active_tile = "participants"  # frozen clients are never read or written

    def __init__(self, fed: FedConfig, loss_fn: LossFn, model=None):
        self.fed = fed
        self.loss_fn = loss_fn
        self.model = model
        self._vg_stacked = api.per_client_value_and_grad_stacked(loss_fn)

    def init(self, params0, rng, init_batch=None):
        sdt = jnp.dtype(self.fed.state_dtype)
        return {
            "x": pt.tree_cast(params0, sdt),
            "round": jnp.zeros((), jnp.int32),
            "step": jnp.zeros((), jnp.int32),
            "rng": rng,
        }

    def round(self, state, batch, mask=None, stale=None):
        fed = self.fed
        m = api.local_client_count(fed.num_clients)
        # stale-x̄ rounds (async engine): each client starts its k0 local
        # steps from the x̄ it last downloaded instead of the fresh
        # broadcast; the local math below is already per-client (stacked),
        # so nothing else changes — and with max_staleness=0 the view IS
        # the fresh broadcast, bitwise.
        if stale is None:
            xc = broadcast_clients(state["x"], m)
        else:
            xc, stale = api.stale_xbar_view(stale, state["x"], mask)

        def local_step(carry, j):
            x, first = carry
            losses, grads = self._vg_stacked(x, batch)
            lr = lr_schedule(fed.lr, state["step"] + j)
            x_new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), x, grads)
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f), first, (losses, grads)
            )
            return (x_new, first), None

        first0 = (
            jnp.zeros((m,), jnp.float32),
            pt.tree_zeros_like(xc),
        )
        (xc_new, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )
        # partial participation: only masked-in clients are aggregated
        # (FedAvg has no per-client carry-over state to freeze). Under a
        # non-uniform staleness weighting, a trajectory started from an
        # s-rounds-old anchor is downweighted by decay in s (post-view
        # `last_used` = the age of the anchor these k0 steps ran against);
        # weights=None (uniform / sync) keeps this path bitwise.
        x_new = api.client_mean(xc_new, mask=mask,
                                weights=api.stale_weights(stale))

        new_state = dict(state)
        new_state.update(
            x=x_new, round=state["round"] + 1, step=state["step"] + fed.k0
        )
        metrics = round_metrics(losses0, grads0, state["round"], mask=mask)
        metrics["local_grad_evals"] = jnp.float32(fed.k0)
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ------------------------------------------------------------ flat round
    def round_flat(self, state, batch, spec, mask=None, stale=None,
                   compressor=None, donate_kernel=False,
                   faults=None, screening=None):
        """`round` on the flat (m, N) trajectory buffer (engine flat=True):
        the k0 local steps update one contiguous array, the gradient
        evaluation is the only pytree boundary
        (`common.flat_value_and_grad`), and the aggregation + diagnostics
        ride ONE fused reduction (`api.flat_round_aggregate`) — eq. (11)
        as the round's single model-size all-reduce under sharding.
        `compressor` routes the uploaded trajectory through the codec
        (decompress-before-reduce, `common.compress_contrib`).

        Overlap (engine-seeded `state["ovl_shard"]`): the round's anchor
        is the all-gather of LAST round's reduce-scattered upload mean
        (`api.flat_overlap_consensus`) — the exact value `state["x"]`
        would hold at a barrier — and the round end reduce-scatters this
        round's trajectories (`api.flat_overlap_aggregate`) instead of
        all-reducing them, so the wire hides behind the next round's k0
        local steps. `state["x"]` lags one round (the engine's
        `overlap_finalize` default gathers the pending slot at run end).
        `donate_kernel` is accepted for round-fn uniformity (FedAvg has
        no Pallas hot path) and ignored."""
        fed = self.fed
        m = api.local_client_count(fed.num_clients)
        ovl = state.get("ovl_shard")
        anchor_x = (state["x"] if ovl is None
                    else api.flat_overlap_consensus(ovl)[0])
        if stale is None:
            xc = broadcast_clients(anchor_x, m)
        else:
            xc, stale = api.stale_xbar_view(stale, anchor_x, mask)
        fvg = flat_value_and_grad(self._vg_stacked, spec)

        def local_step(carry, j):
            x, first = carry
            losses, grads = fvg(x, batch)
            lr = lr_schedule(fed.lr, state["step"] + j)
            x_new = x - lr * grads.astype(x.dtype)
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f), first,
                (losses, grads)
            )
            return (x_new, first), None

        first0 = (jnp.zeros((m,), jnp.float32), jnp.zeros_like(xc))
        (xc_new, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )
        xc_up, ef_new = compress_contrib(compressor, state, xc_new, spec,
                                         mask=mask)
        hardened = faults is not None or screening is not None
        fprev_new = None
        if hardened:
            xc_up, mask, fprev_new, n_scr = api.harden_upload(
                xc_up, mask, spec, faults=faults, screening=screening,
                fault_prev=state.get("fault_prev"),
                round_idx=state["round"])
        if ovl is None:
            x_new, gsq, f_mean, n_sel = api.flat_round_aggregate(
                xc_up, grads0, losses0, participation_vec(losses0, mask),
                spec, mask=mask, weights=api.stale_weights(stale),
            )
        else:
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate(
                xc_up, grads0, losses0, participation_vec(losses0, mask),
                spec, mask=mask, weights=api.stale_weights(stale),
            )
            x_new = anchor_x  # the consensus just consumed; next one is
            # in flight in the slot until the next round's all-gather

        new_state = dict(state)
        new_state.update(
            x=x_new, round=state["round"] + 1, step=state["step"] + fed.k0
        )
        if ovl is not None:
            new_state["ovl_shard"] = slot
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        metrics = round_metrics_flat(gsq, f_mean, n_sel, state["round"])
        metrics["local_grad_evals"] = jnp.float32(fed.k0)
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ----------------------------------------------------- active-set round
    def round_flat_active(self, state, batch, spec, active, stale=None,
                          compressor=None, donate_kernel=False,
                          faults=None, screening=None):
        """`round_flat` on the packed participant tile (store="active"):
        the k0 local trajectories exist only for the (capacity,) gathered
        clients, so the round's working set is (capacity, N) instead of
        (m, N) — FedAvg has no per-client carry, so nothing is scattered
        back. State results are bitwise the dense masked round's; the
        loss/grad diagnostics are participant means (the dense path's
        population means would require contacting every client)."""
        fed = self.fed
        cap = active.capacity
        batch_t = active.gather_tree(batch)
        ovl = state.get("ovl_shard")
        anchor_x = (state["x"] if ovl is None
                    else api.flat_overlap_consensus(ovl)[0])
        if stale is None:
            xc = broadcast_clients(anchor_x, cap)
        else:
            xc, stale = api.stale_xbar_view_active(stale, anchor_x, active)
        fvg = flat_value_and_grad(self._vg_stacked, spec)

        def local_step(carry, j):
            x, first = carry
            losses, grads = fvg(x, batch_t)
            lr = lr_schedule(fed.lr, state["step"] + j)
            x_new = x - lr * grads.astype(x.dtype)
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f), first,
                (losses, grads)
            )
            return (x_new, first), None

        first0 = (jnp.zeros((cap,), jnp.float32), jnp.zeros_like(xc))
        (xc_new, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )
        w = api.stale_weights(stale)
        xc_up, ef_new = compress_contrib_active(compressor, state, xc_new,
                                                spec, active)
        hardened = faults is not None or screening is not None
        fprev_new = None
        if hardened:
            xc_up, active, fprev_new, n_scr = api.harden_upload_active(
                xc_up, active, spec, faults=faults, screening=screening,
                fault_prev=state.get("fault_prev"),
                round_idx=state["round"])
        if ovl is None:
            x_new, gsq, f_mean, n_sel = api.flat_round_aggregate_active(
                xc_up, grads0, losses0, active, spec,
                weights=w,
            )
        else:
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate_active(
                xc_up, grads0, losses0, active, spec,
                weights=w,
            )
            x_new = anchor_x

        new_state = dict(state)
        new_state.update(
            x=x_new, round=state["round"] + 1, step=state["step"] + fed.k0
        )
        if ovl is not None:
            new_state["ovl_shard"] = slot
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        metrics = round_metrics_flat(gsq, f_mean, n_sel, state["round"])
        metrics["local_grad_evals"] = jnp.float32(fed.k0)
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics
