"""FedProx [Li et al. 2020] — per paper §V.D: each client descends the
proximal objective  f_i(x) + (mu/2)||x − x̄||²  with GD, k0 steps between
aggregations (inner_steps GD iterations per step)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core import api
from repro.core.api import LossFn, broadcast_clients
from repro.core.baselines.common import (
    compress_contrib,
    compress_contrib_active,
    flat_value_and_grad,
    lr_schedule,
    participation_vec,
    round_metrics,
    round_metrics_flat,
)
from repro.utils import pytree as pt


class FedProx:
    name = "fedprox"
    # "ef" = compression error-feedback residual (core/compress.py) and
    # "fault_prev" = the fault model's replay buffer (core/faults.py);
    # present only when the engine enables them — absent keys cost nothing
    client_state_keys = ("ef", "fault_prev")
    flat_client_keys = ("ef", "fault_prev")
    flat_global_keys = ("x",)
    active_tile = "participants"  # frozen clients are never read or written

    def __init__(self, fed: FedConfig, loss_fn: LossFn, model=None):
        self.fed = fed
        self.loss_fn = loss_fn
        self.model = model
        self._vg_stacked = api.per_client_value_and_grad_stacked(loss_fn)

    def init(self, params0, rng, init_batch=None):
        sdt = jnp.dtype(self.fed.state_dtype)
        return {
            "x": pt.tree_cast(params0, sdt),
            "round": jnp.zeros((), jnp.int32),
            "step": jnp.zeros((), jnp.int32),
            "rng": rng,
        }

    def round(self, state, batch, mask=None, stale=None):
        fed = self.fed
        m = api.local_client_count(fed.num_clients)
        # stale-x̄ rounds: a straggler both starts from AND proxes toward
        # its last-downloaded anchor (the prox center is the model it
        # actually holds); bitwise-fresh when max_staleness=0.
        if stale is None:
            xc = broadcast_clients(state["x"], m)
        else:
            xc, stale = api.stale_xbar_view(stale, state["x"], mask)

        vg = self._vg_stacked

        def prox_grad(x, plain_grads, anchor):
            return jax.tree.map(
                lambda g, p, a: g + fed.prox_mu * (p - a), plain_grads, x, anchor
            )

        def local_step(carry, j):
            x, first = carry
            lr = lr_schedule(fed.lr, state["step"] + j)

            def inner(x, _):
                losses, grads = vg(x, batch)
                g = prox_grad(x, grads, xc)
                x_new = jax.tree.map(lambda p, gg: p - lr * gg.astype(p.dtype), x, g)
                return x_new, (losses, grads)

            x, (losses, grads) = jax.lax.scan(inner, x, None, length=fed.inner_steps)
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f),
                first,
                (
                    jax.tree.map(lambda a: a[0], losses),
                    jax.tree.map(lambda a: a[0], grads),
                ),
            )
            return (x, first), None

        first0 = (jnp.zeros((m,), jnp.float32), pt.tree_zeros_like(xc))
        (xc_new, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )
        # partial participation: aggregate over masked-in clients only;
        # staleness-aware weights downweight trajectories proxed toward an
        # old anchor (None = uniform = bitwise unweighted)
        x_new = api.client_mean(xc_new, mask=mask,
                                weights=api.stale_weights(stale))

        new_state = dict(state)
        new_state.update(
            x=x_new, round=state["round"] + 1, step=state["step"] + fed.k0
        )
        metrics = round_metrics(losses0, grads0, state["round"], mask=mask)
        metrics["local_grad_evals"] = jnp.float32(fed.k0 * fed.inner_steps)
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ------------------------------------------------------------ flat round
    def round_flat(self, state, batch, spec, mask=None, stale=None,
                   compressor=None, donate_kernel=False,
                   faults=None, screening=None):
        """`round` on the flat (m, N) trajectory buffer: the proximal GD
        loop is contiguous elementwise math, the gradient evaluation the
        only pytree boundary, and eq. (11) + diagnostics one fused
        reduction (see FedAvg.round_flat, incl. the compressor hook and
        the overlap / ignored-`donate_kernel` contract — under overlap
        the prox center is the all-gathered consensus shard)."""
        fed = self.fed
        m = api.local_client_count(fed.num_clients)
        ovl = state.get("ovl_shard")
        anchor_x = (state["x"] if ovl is None
                    else api.flat_overlap_consensus(ovl)[0])
        if stale is None:
            xc = broadcast_clients(anchor_x, m)
        else:
            xc, stale = api.stale_xbar_view(stale, anchor_x, mask)
        fvg = flat_value_and_grad(self._vg_stacked, spec)

        def local_step(carry, j):
            x, first = carry
            lr = lr_schedule(fed.lr, state["step"] + j)

            def inner(x, _):
                losses, grads = fvg(x, batch)
                g = grads + fed.prox_mu * (x - xc)
                x_new = x - lr * g.astype(x.dtype)
                return x_new, (losses, grads)

            x, (losses, grads) = jax.lax.scan(inner, x, None,
                                              length=fed.inner_steps)
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f),
                first,
                (losses[0], grads[0]),
            )
            return (x, first), None

        first0 = (jnp.zeros((m,), jnp.float32), jnp.zeros_like(xc))
        (xc_new, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )
        xc_up, ef_new = compress_contrib(compressor, state, xc_new, spec,
                                         mask=mask)
        hardened = faults is not None or screening is not None
        fprev_new = None
        if hardened:
            xc_up, mask, fprev_new, n_scr = api.harden_upload(
                xc_up, mask, spec, faults=faults, screening=screening,
                fault_prev=state.get("fault_prev"),
                round_idx=state["round"])
        if ovl is None:
            x_new, gsq, f_mean, n_sel = api.flat_round_aggregate(
                xc_up, grads0, losses0, participation_vec(losses0, mask),
                spec, mask=mask, weights=api.stale_weights(stale),
            )
        else:
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate(
                xc_up, grads0, losses0, participation_vec(losses0, mask),
                spec, mask=mask, weights=api.stale_weights(stale),
            )
            x_new = anchor_x

        new_state = dict(state)
        new_state.update(
            x=x_new, round=state["round"] + 1, step=state["step"] + fed.k0
        )
        if ovl is not None:
            new_state["ovl_shard"] = slot
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        metrics = round_metrics_flat(gsq, f_mean, n_sel, state["round"])
        metrics["local_grad_evals"] = jnp.float32(fed.k0 * fed.inner_steps)
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ----------------------------------------------------- active-set round
    def round_flat_active(self, state, batch, spec, active, stale=None,
                          compressor=None, donate_kernel=False,
                          faults=None, screening=None):
        """`round_flat` on the packed participant tile (store="active"):
        proximal GD trajectories exist only for the gathered clients (the
        prox center is each participant's own anchor view). See
        FedAvg.round_flat_active for the tile/diagnostic contract."""
        fed = self.fed
        cap = active.capacity
        batch_t = active.gather_tree(batch)
        ovl = state.get("ovl_shard")
        anchor_x = (state["x"] if ovl is None
                    else api.flat_overlap_consensus(ovl)[0])
        if stale is None:
            xc = broadcast_clients(anchor_x, cap)
        else:
            xc, stale = api.stale_xbar_view_active(stale, anchor_x, active)
        fvg = flat_value_and_grad(self._vg_stacked, spec)

        def local_step(carry, j):
            x, first = carry
            lr = lr_schedule(fed.lr, state["step"] + j)

            def inner(x, _):
                losses, grads = fvg(x, batch_t)
                g = grads + fed.prox_mu * (x - xc)
                x_new = x - lr * g.astype(x.dtype)
                return x_new, (losses, grads)

            x, (losses, grads) = jax.lax.scan(inner, x, None,
                                              length=fed.inner_steps)
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f),
                first,
                (losses[0], grads[0]),
            )
            return (x, first), None

        first0 = (jnp.zeros((cap,), jnp.float32), jnp.zeros_like(xc))
        (xc_new, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (xc, first0), jnp.arange(fed.k0)
        )
        w = api.stale_weights(stale)
        xc_up, ef_new = compress_contrib_active(compressor, state, xc_new,
                                                spec, active)
        hardened = faults is not None or screening is not None
        fprev_new = None
        if hardened:
            xc_up, active, fprev_new, n_scr = api.harden_upload_active(
                xc_up, active, spec, faults=faults, screening=screening,
                fault_prev=state.get("fault_prev"),
                round_idx=state["round"])
        if ovl is None:
            x_new, gsq, f_mean, n_sel = api.flat_round_aggregate_active(
                xc_up, grads0, losses0, active, spec,
                weights=w,
            )
        else:
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate_active(
                xc_up, grads0, losses0, active, spec,
                weights=w,
            )
            x_new = anchor_x

        new_state = dict(state)
        new_state.update(
            x=x_new, round=state["round"] + 1, step=state["step"] + fed.k0
        )
        if ovl is not None:
            new_state["ovl_shard"] = slot
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        metrics = round_metrics_flat(gsq, f_mean, n_sel, state["round"])
        metrics["local_grad_evals"] = jnp.float32(fed.k0 * fed.inner_steps)
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics
