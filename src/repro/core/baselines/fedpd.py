"""FedPD [Zhang et al. 2021] — oracle choice I / option I per paper §V.D:
primal-dual with inexact local solves.

Per inner step, each client approximately solves
    x_i ≈ argmin f_i(x) + <lam_i, x − x̄_i> + 1/(2 eta) ||x − x̄_i||²
with `inner_steps` GD iterations (lr = gamma_k), then
    lam_i += (x_i − x̄_i)/eta ;   x̄_i ← x_i + eta*lam_i.
Aggregation every k0 steps: x̄ = mean_i x̄_i (deterministic, matching the
paper's modification of FedPD's probabilistic aggregation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core import api
from repro.core.api import LossFn, broadcast_clients
from repro.core.baselines.common import (
    compress_contrib,
    compress_contrib_active,
    flat_value_and_grad,
    lr_schedule,
    participation_vec,
    round_metrics,
    round_metrics_flat,
)
from repro.utils import pytree as pt


class FedPD:
    name = "fedpd"
    # "ef" = compression error-feedback residual (core/compress.py) and
    # "fault_prev" = the fault model's replay buffer (core/faults.py);
    # present only when the engine enables them — absent keys cost nothing
    client_state_keys = ("lam", "ef", "fault_prev")
    flat_client_keys = ("lam", "ef", "fault_prev")
    flat_global_keys = ("x",)
    active_tile = "participants"  # frozen clients keep their duals untouched

    def __init__(self, fed: FedConfig, loss_fn: LossFn, model=None):
        self.fed = fed
        self.loss_fn = loss_fn
        self.model = model
        self._vg_stacked = api.per_client_value_and_grad_stacked(loss_fn)

    def init(self, params0, rng, init_batch=None):
        sdt = jnp.dtype(self.fed.state_dtype)
        m = self.fed.num_clients
        x = pt.tree_cast(params0, sdt)
        return {
            "x": x,
            "lam": pt.tree_zeros_like(broadcast_clients(x, m)),
            "round": jnp.zeros((), jnp.int32),
            "step": jnp.zeros((), jnp.int32),
            "rng": rng,
        }

    def round(self, state, batch, mask=None, stale=None):
        fed = self.fed
        m = api.local_client_count(fed.num_clients)
        eta = fed.fedpd_eta
        # stale-x̄ rounds: the per-client primal-dual anchor x̄_i resets to
        # the client's last-downloaded global model, not the fresh one —
        # the primal-dual updates tolerate the bounded perturbation
        # (arXiv:2210.08106); bitwise-fresh when max_staleness=0.
        if stale is None:
            anchors = broadcast_clients(state["x"], m)
        else:
            anchors, stale = api.stale_xbar_view(stale, state["x"], mask)

        vg = self._vg_stacked

        def local_step(carry, j):
            anchor, lam, first = carry
            lr = lr_schedule(fed.lr, state["step"] + j)

            def inner(x, _):
                losses, grads = vg(x, batch)
                g = jax.tree.map(
                    lambda gg, xx, ll, aa: gg + ll + (xx - aa) / eta,
                    grads, x, lam, anchor,
                )
                x_new = jax.tree.map(lambda p, d: p - lr * d.astype(p.dtype), x, g)
                return x_new, (losses, grads)

            xi, (losses, grads) = jax.lax.scan(
                inner, anchor, None, length=fed.inner_steps
            )
            lam_new = jax.tree.map(
                lambda ll, xx, aa: ll + (xx - aa) / eta, lam, xi, anchor
            )
            anchor_new = jax.tree.map(
                lambda xx, ll: xx + eta * ll, xi, lam_new
            )
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f),
                first,
                (
                    jax.tree.map(lambda a: a[0], losses),
                    jax.tree.map(lambda a: a[0], grads),
                ),
            )
            return (anchor_new, lam_new, first), None

        first0 = (jnp.zeros((m,), jnp.float32), pt.tree_zeros_like(anchors))
        (anchors_new, lam_new, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (anchors, state["lam"], first0), jnp.arange(fed.k0)
        )
        # partial participation: frozen clients keep their duals and do not
        # contribute their (stale) anchors to the aggregation
        if mask is not None:
            lam_new = api.masked_update(mask, lam_new, state["lam"])
        # staleness-aware weights downweight anchors rebuilt from an old
        # download (None = uniform = bitwise unweighted)
        x_new = api.client_mean(anchors_new, mask=mask,
                                weights=api.stale_weights(stale))

        new_state = dict(state)
        new_state.update(
            x=x_new,
            lam=lam_new,
            round=state["round"] + 1,
            step=state["step"] + fed.k0,
        )
        metrics = round_metrics(losses0, grads0, state["round"], mask=mask)
        metrics["local_grad_evals"] = jnp.float32(fed.k0 * fed.inner_steps)
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ------------------------------------------------------------ flat round
    def round_flat(self, state, batch, spec, mask=None, stale=None,
                   compressor=None, donate_kernel=False,
                   faults=None, screening=None):
        """`round` on the flat (m, N) buffers: per-client primal-dual
        anchors and duals are contiguous arrays, the gradient evaluation
        the only pytree boundary, and eq. (11) + diagnostics one fused
        reduction (see FedAvg.round_flat, incl. the compressor hook —
        the uploaded anchor x̄_i is what goes through the codec, the
        duals stay client-resident — and the overlap /
        ignored-`donate_kernel` contract)."""
        fed = self.fed
        m = api.local_client_count(fed.num_clients)
        eta = fed.fedpd_eta
        ovl = state.get("ovl_shard")
        anchor_x = (state["x"] if ovl is None
                    else api.flat_overlap_consensus(ovl)[0])
        if stale is None:
            anchors = broadcast_clients(anchor_x, m)
        else:
            anchors, stale = api.stale_xbar_view(stale, anchor_x, mask)
        fvg = flat_value_and_grad(self._vg_stacked, spec)

        def local_step(carry, j):
            anchor, lam, first = carry
            lr = lr_schedule(fed.lr, state["step"] + j)

            def inner(x, _):
                losses, grads = fvg(x, batch)
                g = grads + lam + (x - anchor) / eta
                x_new = x - lr * g.astype(x.dtype)
                return x_new, (losses, grads)

            xi, (losses, grads) = jax.lax.scan(
                inner, anchor, None, length=fed.inner_steps
            )
            lam_new = lam + (xi - anchor) / eta
            anchor_new = xi + eta * lam_new
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f),
                first,
                (losses[0], grads[0]),
            )
            return (anchor_new, lam_new, first), None

        first0 = (jnp.zeros((m,), jnp.float32), jnp.zeros_like(anchors))
        (anchors_new, lam_new, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (anchors, state["lam"], first0), jnp.arange(fed.k0)
        )
        if mask is not None:
            lam_new = api.masked_update(mask, lam_new, state["lam"])
        anchors_up, ef_new = compress_contrib(compressor, state, anchors_new,
                                              spec, mask=mask)
        # faults/screening shrink the AGGREGATION mask only — the dual
        # update above keeps the original participation mask (the client
        # advanced its local state; only its upload was lost/rejected)
        hardened = faults is not None or screening is not None
        fprev_new = None
        if hardened:
            anchors_up, mask, fprev_new, n_scr = api.harden_upload(
                anchors_up, mask, spec, faults=faults, screening=screening,
                fault_prev=state.get("fault_prev"),
                round_idx=state["round"])
        if ovl is None:
            x_new, gsq, f_mean, n_sel = api.flat_round_aggregate(
                anchors_up, grads0, losses0,
                participation_vec(losses0, mask),
                spec, mask=mask, weights=api.stale_weights(stale),
            )
        else:
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate(
                anchors_up, grads0, losses0,
                participation_vec(losses0, mask),
                spec, mask=mask, weights=api.stale_weights(stale),
            )
            x_new = anchor_x

        new_state = dict(state)
        new_state.update(
            x=x_new,
            lam=lam_new,
            round=state["round"] + 1,
            step=state["step"] + fed.k0,
        )
        if ovl is not None:
            new_state["ovl_shard"] = slot
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        metrics = round_metrics_flat(gsq, f_mean, n_sel, state["round"])
        metrics["local_grad_evals"] = jnp.float32(fed.k0 * fed.inner_steps)
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics

    # ----------------------------------------------------- active-set round
    def round_flat_active(self, state, batch, spec, active, stale=None,
                          compressor=None, donate_kernel=False,
                          faults=None, screening=None):
        """`round_flat` on the packed participant tile (store="active"):
        the duals of the round's participants are GATHERED from the resident
        (m, N) `lam` buffer, advanced on the (capacity, N) tile, and
        SCATTERED back — frozen clients' rows are never touched, which is
        exactly the dense path's `masked_update` freeze, row for row. The
        padded tail of the tile is dropped at the scatter (sentinel index),
        so no masking of the dual update is needed."""
        fed = self.fed
        cap = active.capacity
        eta = fed.fedpd_eta
        batch_t = active.gather_tree(batch)
        ovl = state.get("ovl_shard")
        anchor_x = (state["x"] if ovl is None
                    else api.flat_overlap_consensus(ovl)[0])
        if stale is None:
            anchors = broadcast_clients(anchor_x, cap)
        else:
            anchors, stale = api.stale_xbar_view_active(stale, anchor_x,
                                                        active)
        lam_t = active.gather_state(state["lam"])
        fvg = flat_value_and_grad(self._vg_stacked, spec)

        def local_step(carry, j):
            anchor, lam, first = carry
            lr = lr_schedule(fed.lr, state["step"] + j)

            def inner(x, _):
                losses, grads = fvg(x, batch_t)
                g = grads + lam + (x - anchor) / eta
                x_new = x - lr * g.astype(x.dtype)
                return x_new, (losses, grads)

            xi, (losses, grads) = jax.lax.scan(
                inner, anchor, None, length=fed.inner_steps
            )
            lam_new = lam + (xi - anchor) / eta
            anchor_new = xi + eta * lam_new
            first = jax.tree.map(
                lambda f, new: jnp.where(j == 0, new, f),
                first,
                (losses[0], grads[0]),
            )
            return (anchor_new, lam_new, first), None

        first0 = (jnp.zeros((cap,), jnp.float32), jnp.zeros_like(anchors))
        (anchors_new, lam_new_t, (losses0, grads0)), _ = jax.lax.scan(
            local_step, (anchors, lam_t, first0), jnp.arange(fed.k0)
        )
        lam_new = active.scatter_state(state["lam"], lam_new_t)
        w = api.stale_weights(stale)
        anchors_up, ef_new = compress_contrib_active(compressor, state,
                                                     anchors_new, spec,
                                                     active)
        hardened = faults is not None or screening is not None
        fprev_new = None
        if hardened:
            anchors_up, active, fprev_new, n_scr = api.harden_upload_active(
                anchors_up, active, spec, faults=faults,
                screening=screening, fault_prev=state.get("fault_prev"),
                round_idx=state["round"])
        if ovl is None:
            x_new, gsq, f_mean, n_sel = api.flat_round_aggregate_active(
                anchors_up, grads0, losses0, active, spec,
                weights=w,
            )
        else:
            slot, gsq, f_mean, n_sel = api.flat_overlap_aggregate_active(
                anchors_up, grads0, losses0, active, spec,
                weights=w,
            )
            x_new = anchor_x

        new_state = dict(state)
        new_state.update(
            x=x_new,
            lam=lam_new,
            round=state["round"] + 1,
            step=state["step"] + fed.k0,
        )
        if ovl is not None:
            new_state["ovl_shard"] = slot
        if ef_new is not None:
            new_state["ef"] = ef_new
        if fprev_new is not None:
            new_state["fault_prev"] = fprev_new
        metrics = round_metrics_flat(gsq, f_mean, n_sel, state["round"])
        metrics["local_grad_evals"] = jnp.float32(fed.k0 * fed.inner_steps)
        if hardened:
            metrics["screened"] = n_scr
        if stale is not None:
            return new_state, stale, metrics
        return new_state, metrics
