"""Config system: typed, frozen dataclasses + the four assigned input shapes.

Every architecture in src/repro/configs/ builds a ModelConfig; launchers
combine it with a ShapeConfig (one of the four assigned input shapes), a
MeshConfig and — for training — a FedConfig selecting the federated
algorithm (FedGiA or one of the paper's comparison baselines).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture definition (decoder-only backbone).

    Families: dense | moe | ssm | hybrid | vlm | audio.
    attention_type: gqa | mla | rwkv | hybrid (parallel attn+mamba heads).
    input_mode: tokens | embeds (audio frontend stub) | tokens+embeds (vlm).
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> d_ff)
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    first_dense_layers: int = 0  # deepseek-v3: leading dense layers
    router_aux_coef: float = 0.0

    # --- MLA (deepseek-v3) ---
    attention_type: str = "gqa"
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / hybrid ---
    ssm_state: int = 0
    rwkv_head_size: int = 64

    # --- long-context policy ---
    sliding_window: int = 8192  # used ONLY when long_context mode is on

    # --- multi-token prediction aux head (deepseek-v3) ---
    mtp: bool = False

    # --- modality frontend stub ---
    input_mode: str = "tokens"
    embed_prefix_len: int = 0  # vlm: number of patch-embedding tokens

    dtype: str = "bfloat16"
    remat: bool = True
    # scan_layers=False unrolls the layer stack AND the attention kv-block
    # loop into straight-line HLO — used by the dry-run cost-extrapolation
    # pass because XLA cost_analysis counts lax.scan bodies ONCE (trip
    # counts are not multiplied). Production configs keep scan=True.
    scan_layers: bool = True
    source: str = ""  # citation (hf model card / arXiv id)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.moe and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        assert self.num_heads == 0 or self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads={self.num_heads} not divisible by "
            f"num_kv_heads={self.num_kv_heads}"
        )

    # ---------------------------------------------------------------- reduced
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts.

        Keeps the family/attention type identical so the smoke test
        exercises the same code path as the full config.
        """
        d_model = min(self.d_model, 256)
        n_heads = max(2, min(self.num_heads, 4))
        ratio = max(1, self.num_heads // max(self.num_kv_heads, 1))
        n_kv = max(1, n_heads // min(ratio, n_heads))
        changes = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=64,
            embed_prefix_len=min(self.embed_prefix_len, 8),
        )
        if self.moe:
            changes.update(
                num_experts=min(self.num_experts, 4),
                experts_per_token=min(self.experts_per_token, 2),
                moe_d_ff=min(self.moe_d_ff, 256),
                first_dense_layers=min(self.first_dense_layers, 1),
            )
        if self.attention_type == "mla":
            changes.update(
                q_lora_rank=min(self.q_lora_rank, 64),
                kv_lora_rank=min(self.kv_lora_rank, 32),
                qk_rope_dim=16,
                qk_nope_dim=16,
                v_head_dim=d_model // n_heads,
            )
        if self.ssm_state:
            changes.update(ssm_state=min(self.ssm_state, 8))
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------- accounting
    def param_count(self) -> int:
        """Analytic parameter count (matches models/transformer.py init)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        n_emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        if self.attention_type in ("gqa", "hybrid"):
            hd = self.head_dim
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            per_layer += q + kv + o
            if self.qkv_bias:
                per_layer += (self.num_heads + 2 * self.num_kv_heads) * hd
        elif self.attention_type == "mla":
            qr = self.q_lora_rank or d
            per_layer += d * qr + qr * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
            per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
            per_layer += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
            per_layer += self.num_heads * self.v_head_dim * d
        if self.attention_type == "rwkv":
            # rwkv6 time-mix: r,k,v,g,o + decay params (approx)
            per_layer += 5 * d * d + 2 * d
        if self.attention_type == "hybrid" and self.ssm_state:
            # mamba head branch: in_proj (x,z), dt, B, C, out_proj (approx)
            per_layer += 2 * d * d + d * self.ssm_state * 2 + d * d
        # mlp
        moe_layers = L - self.first_dense_layers if self.moe else 0
        dense_layers = L - moe_layers
        dense_mlp = 3 * d * self.d_ff
        per_expert = 3 * d * self.moe_d_ff
        total = n_emb + L * per_layer + 2 * d  # final norm + per-layer norms approx
        total += dense_layers * dense_mlp
        if self.moe:
            total += moe_layers * (
                self.num_experts * per_expert
                + self.num_shared_experts * per_expert
                + d * self.num_experts  # router
                + (dense_mlp if self.dense_residual else 0)
            )
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed-in experts)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.moe_d_ff
        moe_layers = self.num_layers - self.first_dense_layers
        inactive = moe_layers * per_expert * (
            self.num_experts - self.experts_per_token
        )
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Federated-algorithm selection + FedGiA hyper-parameters (paper §V.B)."""

    algorithm: str = "fedgia"  # fedgia | fedavg | fedprox | fedpd | scaffold
    num_clients: int = 16
    k0: int = 5  # local steps between communications
    alpha: float = 0.5  # |C| / m, client-selection fraction
    sigma_t: float = 0.15  # sigma = t * r / m (paper Table III)
    lipschitz: float = 1.0  # r (estimated online when auto_lipschitz)
    auto_lipschitz: bool = False
    h_policy: str = "diag_ema"  # diag_ema | scalar | gram (linear models only)
    collapsed: bool = True  # beyond-paper exact closed-form round (DESIGN §6 B1)
    # flat-buffer round path (engine `flat=True`): route the collapsed
    # ADMM/GD branch through the batched Pallas kernel
    # (kernels/fedgia_update). None = auto (kernel on TPU, fused jnp
    # closed form elsewhere); kernel_interpret runs the kernel in Pallas
    # interpret mode (CPU tests).
    use_kernel: Optional[bool] = None
    kernel_interpret: bool = False
    client_axes: Tuple[str, ...] = ("data",)  # mesh axes that enumerate clients
    # §Perf knobs (see EXPERIMENTS.md):
    # fsdp_axes: additionally shard client-state inner dims over these mesh
    #   axes (FSDP) — required to fit >100B-param archs with few clients.
    fsdp_axes: Tuple[str, ...] = ()
    # replicate_params: keep model params replicated over `model` and run
    #   pure data-parallel compute within the client (gradient all-reduce
    #   once per round instead of per-layer TP activation all-reduces) —
    #   the right regime for small archs where TP is overkill.
    replicate_params: bool = False
    # baseline hyper-parameters (paper §V.D)
    lr: float = 0.01
    prox_mu: float = 1e-4
    inner_steps: int = 5  # FedProx/FedPD inner GD steps
    fedpd_eta: float = 1.0
    state_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100  # communication rounds
    seed: int = 0
    log_every: int = 10
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    tol: float = 0.0  # grad-norm^2 stopping tolerance (paper eq. 35); 0 = off


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    long_context: bool = False  # sliding-window ring-buffer KV cache
    max_cache_len: int = 32_768
    decode_dtype: str = "bfloat16"
