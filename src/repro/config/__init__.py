from repro.config.base import (
    FedConfig,
    MeshConfig,
    ModelConfig,
    ServeConfig,
    ShapeConfig,
    TrainConfig,
    INPUT_SHAPES,
)
