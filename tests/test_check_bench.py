"""tools/check_bench.py --update-baseline must not lose curation keys.

The committed baselines carry a hand-written top-level `_meta` block
(regeneration command + what the numbers mean) that benchmark dumps
don't produce. The old implementation was a plain file copy, so every
refresh silently dropped `_meta` and it had to be hand-restored in
review. `update_baseline` carries every top-level `_*` key of the old
baseline that the fresh dump lacks.
"""
import importlib.util
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", ROOT / "tools" / "check_bench.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["check_bench"] = mod
    spec.loader.exec_module(mod)
    return mod


cb = _load_check_bench()


def test_update_baseline_preserves_meta(tmp_path, capsys):
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "current.json"
    meta = {"generated_with": "benchmarks/run.py", "note": "hand-written"}
    baseline.write_text(json.dumps(
        {"_meta": meta, "engine": {"paths": {"scan": {"rounds_per_s": 10.0}}}}))
    current.write_text(json.dumps(
        {"engine": {"paths": {"scan": {"rounds_per_s": 12.0}}}}))
    cb.update_baseline(current, baseline)
    out = json.loads(baseline.read_text())
    assert out["_meta"] == meta
    assert out["engine"]["paths"]["scan"]["rounds_per_s"] == 12.0
    assert list(out)[0] == "_meta"  # meta stays on top for readers
    assert "kept _meta" in capsys.readouterr().out


def test_update_baseline_fresh_meta_wins(tmp_path):
    """A dump that DOES carry its own _meta is authoritative — no merge."""
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "current.json"
    baseline.write_text(json.dumps({"_meta": {"note": "old"}, "engine": {}}))
    current.write_text(json.dumps({"_meta": {"note": "new"}, "engine": {}}))
    cb.update_baseline(current, baseline)
    assert json.loads(baseline.read_text())["_meta"] == {"note": "new"}


def test_update_baseline_without_existing_baseline(tmp_path):
    baseline = tmp_path / "baseline.json"
    current = tmp_path / "current.json"
    current.write_text(json.dumps({"engine": {"paths": {}}}))
    cb.update_baseline(current, baseline)
    assert json.loads(baseline.read_text()) == {"engine": {"paths": {}}}


def test_committed_baselines_still_carry_meta():
    """Anchor the invariant the fix exists for: both committed baselines
    keep their _meta block."""
    for name in ("BENCH_engine.baseline.json", "BENCH_wallclock.baseline.json"):
        data = json.loads(
            (ROOT / "benchmarks" / "baselines" / name).read_text())
        assert "generated_with" in data["_meta"], name
        assert "note" in data["_meta"], name
