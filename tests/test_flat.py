"""Flat-buffer round engine equivalence (engine `flat=True`, the default).

The flat path ravels the model-shaped state once into contiguous
lane-padded buffers and runs every round on them (`algo.round_flat`);
the pytree path (`flat=False`, `--no-flat`) is the per-leaf original.
On a single device the two must be BITWISE identical — history AND final
state — for all five algorithms across scan/legacy, masked, async and
clocked rounds: the flat round mirrors the pytree round operation for
operation on the raveled layout (see docs/engine.md). fp tolerance is
allowed only where the Pallas kernel (interpret mode on CPU) or the
sharded fused psum replaces the mirrored arithmetic.

Also covers: the RavelSpec layout helpers, the `--chunk auto` autotuner's
determinism, and (subprocess) the flat sharded round's ONE model-size
all-reduce.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fake_device_env
from repro.config import FedConfig
from repro.core import make_algorithm, make_policy, run_rounds
from repro.core.clock import ComputeClock
from repro.core.engine import flatten_state, unflatten_state
from repro.data import linreg_noniid
from repro.models import LeastSquares
from repro.utils import pytree as pt

M, N, D = 8, 20, 400
ROUNDS = 12
CHUNK = 5  # exercises full + partial chunks

ALGO_SETUPS = {
    "fedgia": dict(sigma_t=0.2, h_policy="scalar", alpha=0.5),
    "fedgia_diag": dict(sigma_t=0.2, h_policy="diag_ema", alpha=0.5),
    "fedgia_unrolled": dict(sigma_t=0.2, h_policy="diag_ema", alpha=0.5,
                            collapsed=False),
    "fedgia_gram": dict(sigma_t=0.2, h_policy="gram", alpha=0.5,
                        collapsed=False),
    "fedavg": dict(lr=0.01),
    "fedprox": dict(lr=0.002, prox_mu=1e-4, inner_steps=3),
    "fedpd": dict(lr=0.05, fedpd_eta=1.0, inner_steps=3),
    "scaffold": dict(lr=0.01),
}
FIVE = ["fedgia_diag", "fedavg", "fedprox", "fedpd", "scaffold"]


@pytest.fixture(scope="module")
def problem():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def _make(problem, key, **overrides):
    model, batch = problem
    name = "fedgia" if key.startswith("fedgia") else key
    kwargs = dict(algorithm=name, num_clients=M, k0=3)
    kwargs.update(ALGO_SETUPS[key])
    kwargs.update(overrides)
    fed = FedConfig(**kwargs)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    return algo, state


def _assert_bitwise(res, ref):
    assert res.rounds_run == ref.rounds_run
    assert set(res.history) == set(ref.history)
    for k in ref.history:
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), f"state[{key!r}] diverged"


# ---------------------------------------------------------------- RavelSpec
def test_ravel_spec_layout_and_roundtrip():
    tree = {
        "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": jnp.arange(3, dtype=jnp.float32) + 10.0,
    }
    spec = pt.ravel_spec(tree)
    assert spec.size == 9
    assert spec.padded_size == pt.LANES  # lane-padded
    flat = spec.ravel(tree)
    assert flat.shape == (pt.LANES,)
    assert float(jnp.abs(flat[spec.size:]).max()) == 0.0  # zero tail
    back = spec.unravel(flat)
    ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), tree, back)
    assert all(jax.tree.leaves(ok))


def test_ravel_spec_stacked_roundtrip_and_cache():
    tree = {"w": jnp.ones((4, 2, 3)), "b": jnp.zeros((4, 5))}
    spec = pt.ravel_spec({"w": tree["w"][0], "b": tree["b"][0]})
    flat = spec.ravel_stacked(tree)
    assert flat.shape == (4, spec.padded_size)
    back = spec.unravel_stacked(flat)
    ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), tree, back)
    assert all(jax.tree.leaves(ok))
    # the cache returns the SAME object for the same layout, so jit caches
    # keyed on the closed-over spec are reused across run_rounds calls
    assert pt.ravel_spec({"w": tree["w"][0], "b": tree["b"][0]}) is spec


def test_ravel_exact_lane_multiple_not_padded():
    tree = {"w": jnp.ones((pt.LANES,))}
    spec = pt.ravel_spec(tree)
    assert spec.size == spec.padded_size == pt.LANES


def test_flatten_state_roundtrip(problem):
    algo, state = _make(problem, "scaffold")
    spec = pt.ravel_spec(state["x"])
    flat = flatten_state(algo, state, spec)
    assert flat["x"].shape == (spec.padded_size,)
    assert flat["c"].shape == (spec.padded_size,)
    assert flat["ci"].shape == (M, spec.padded_size)
    back = unflatten_state(algo, flat, spec)
    for k in state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          state[k], back[k])
        assert all(jax.tree.leaves(ok)), k


# ---------------------------------------------------- flat == pytree, sync
@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
def test_flat_matches_pytree_scan(problem, algo_key):
    algo, state = _make(problem, algo_key)
    _, batch = problem
    ref = run_rounds(algo, state, batch, ROUNDS, chunk_size=CHUNK, flat=False)
    res = run_rounds(algo, state, batch, ROUNDS, chunk_size=CHUNK, flat=True)
    _assert_bitwise(res, ref)


@pytest.mark.parametrize("algo_key", ["fedgia_diag", "fedavg", "scaffold"])
def test_flat_matches_pytree_legacy(problem, algo_key):
    algo, state = _make(problem, algo_key)
    _, batch = problem
    ref = run_rounds(algo, state, batch, ROUNDS, scan=False, flat=False)
    res = run_rounds(algo, state, batch, ROUNDS, scan=False, flat=True)
    _assert_bitwise(res, ref)


# ------------------------------------------- masked / async / clocked flat
@pytest.mark.parametrize("algo_key", FIVE)
def test_flat_matches_pytree_masked(problem, algo_key):
    algo, state = _make(problem, algo_key)
    _, batch = problem
    pol = make_policy("straggler", M, 0.5, seed=0, drop_prob=0.3,
                      horizon=ROUNDS)
    ref = run_rounds(algo, state, batch, ROUNDS, participation=pol,
                     flat=False)
    res = run_rounds(algo, state, batch, ROUNDS, participation=pol,
                     flat=True)
    _assert_bitwise(res, ref)


@pytest.mark.parametrize("algo_key", FIVE)
def test_flat_matches_pytree_async(problem, algo_key):
    """The stale anchor buffer is one (m, N) array on the flat path —
    still bitwise the per-leaf pytree buffers."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    pol = make_policy("straggler", M, 0.5, seed=0, drop_prob=0.3,
                      horizon=ROUNDS)
    kw = dict(participation=pol, async_rounds=True, max_staleness=2)
    ref = run_rounds(algo, state, batch, ROUNDS, flat=False, **kw)
    res = run_rounds(algo, state, batch, ROUNDS, flat=True, **kw)
    _assert_bitwise(res, ref)


@pytest.mark.parametrize("algo_key", ["fedgia_diag", "fedavg", "scaffold"])
def test_flat_matches_pytree_clocked_weighted(problem, algo_key):
    algo, state = _make(problem, algo_key)
    _, batch = problem
    clk = ComputeClock(M, 1.0 + (np.arange(M) % 3))
    kw = dict(clock=clk, max_staleness=2, stale_weighting="poly")
    ref = run_rounds(algo, state, batch, ROUNDS, flat=False, **kw)
    res = run_rounds(algo, state, batch, ROUNDS, flat=True, **kw)
    _assert_bitwise(res, ref)


def test_flat_early_stop_matches(problem):
    algo, state = _make(problem, "fedgia", k0=5)
    _, batch = problem
    ref = run_rounds(algo, state, batch, 300, tol=1e-7, chunk_size=13,
                     flat=False)
    res = run_rounds(algo, state, batch, 300, tol=1e-7, chunk_size=13,
                     flat=True)
    assert ref.stopped_early and res.stopped_early
    _assert_bitwise(res, ref)


# ------------------------------------------------------------ kernel path
@pytest.mark.parametrize("h_policy", ["scalar", "diag_ema"])
def test_flat_kernel_interpret_matches(problem, h_policy):
    """The batched Pallas kernel (interpret mode on CPU) is fp-equivalent
    to the fused jnp closed form on the flat round hot path."""
    algo, state = _make(problem, "fedgia", h_policy=h_policy)
    model, batch = problem
    fed_k = FedConfig(algorithm="fedgia", num_clients=M, k0=3, sigma_t=0.2,
                      h_policy=h_policy, alpha=0.5, use_kernel=True,
                      kernel_interpret=True)
    algo_k = make_algorithm(fed_k, model.loss, model=model)
    ref = run_rounds(algo, state, batch, 6, flat=True)
    res = run_rounds(algo_k, state, batch, 6, flat=True)
    assert res.rounds_run == ref.rounds_run
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=2e-5, atol=2e-6, err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(
            lambda a, b: bool(jnp.allclose(a, b, rtol=2e-5, atol=2e-6)),
            res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), key


def test_use_kernel_rejected_nowhere(problem):
    """use_kernel=None auto-selects by backend — on CPU the flat round
    takes the fused jnp path and stays bitwise the pytree path."""
    algo, state = _make(problem, "fedgia_diag")
    assert algo._use_kernel() == (jax.default_backend() == "tpu")
    algo_g, _ = _make(problem, "fedgia_gram")
    assert not algo_g._use_kernel()  # gram never routes to the kernel


# ------------------------------------------------------------- chunk auto
def test_chunk_auto_is_deterministic(problem):
    """`--chunk auto` times candidate chunk lengths on the live run; the
    rounds EXECUTED are identical whatever the timings, so under tol<=0
    the result is bitwise the fixed-chunk run."""
    algo, state = _make(problem, "fedgia_diag")
    _, batch = problem
    ref = run_rounds(algo, state, batch, 60, chunk_size=7)
    res = run_rounds(algo, state, batch, 60, chunk_size="auto")
    _assert_bitwise(res, ref)


def test_chunk_auto_short_run(problem):
    """Fewer rounds than the first candidate still runs them all."""
    algo, state = _make(problem, "fedgia_diag")
    _, batch = problem
    res = run_rounds(algo, state, batch, 5, chunk_size="auto")
    assert res.rounds_run == 5


def test_chunk_auto_validation(problem):
    algo, state = _make(problem, "fedgia_diag")
    _, batch = problem
    with pytest.raises(ValueError, match="auto"):
        run_rounds(algo, state, batch, 4, chunk_size="fastest")
    with pytest.raises(ValueError, match="legacy"):
        run_rounds(algo, state, batch, 4, chunk_size="auto", scan=False)
    # under a mesh there is no AOT warm-up: candidate timings would
    # measure compilation, not rounds — rejected rather than mis-tuned
    with pytest.raises(ValueError, match="mesh"):
        run_rounds(algo, state, batch, 4, chunk_size="auto",
                   mesh=object())


# ------------------------------------- sharded: ONE model-size all-reduce
_SHARDED_FLAT_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from hlo_guard import assert_barrier_round
    from repro.config import FedConfig
    from repro.core import api, engine, make_algorithm, make_policy, run_rounds
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares
    from repro.utils import pytree as pt

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)

    def round_hlo(algo_name, stale):
        fed = FedConfig(algorithm=algo_name, num_clients=m, k0=3, alpha=1.0,
                        sigma_t=0.3, h_policy="diag_ema", lr=0.01)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        spec = pt.ravel_spec(s0["x"])
        s0f = engine.flatten_state(algo, s0, spec)
        rf = engine.make_round_fn(algo, mesh, masked=True, stale=stale,
                                  flat_spec=spec)
        st, b = engine.shard_inputs(algo, s0f, batch, mesh)
        args = (st, b, jnp.ones((m,), bool))
        if stale:
            args = args + (api.init_stale_xbar(s0f["x"], m, 2),)
        return jax.jit(rf).lower(*args).compile().as_text()

    for name in ("fedgia", "fedavg", "fedprox", "fedpd", "scaffold"):
        for stale in (False, True):
            assert_barrier_round(round_hlo(name, stale), f"{name}/stale={stale}")

    # and the flat sharded RUN matches the flat single-device run
    fed = FedConfig(algorithm="fedgia", num_clients=m, k0=3, alpha=1.0,
                    sigma_t=0.3, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    s0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                   init_batch=batch)
    pol = make_policy("straggler", m, 0.5, seed=0, drop_prob=0.3, horizon=10)
    kw = dict(participation=pol, async_rounds=True, max_staleness=2)
    ref = run_rounds(algo, s0, batch, 10, **kw)
    res = run_rounds(algo, s0, batch, 10, mesh=mesh, **kw)
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-4, atol=1e-6, err_msg=k)
    print("FLAT_SHARDED_OK one model-size all-reduce for all five")
    """
)


def test_flat_sharded_one_all_reduce_and_parity():
    """The flat sharded round lowers to exactly ONE model-size all-reduce
    for ALL FIVE algorithms (eq. (11) as the round's single model-size
    communication; the grad-norm metric rides a reduce-scatter instead),
    and the flat sharded run matches the flat single-device run."""
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_FLAT_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=900,
    )
    assert "FLAT_SHARDED_OK" in out.stdout, out.stdout + out.stderr
