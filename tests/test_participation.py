"""In-engine partial participation: equivalence and state-freezing.

Acceptance contract of the participation subsystem:
  * alpha=1.0 / uniform policy: the masked engine is BITWISE identical to
    the full-participation path for all five algorithms, on both the scan
    and legacy engine paths (the mask plumbing must cost nothing when
    everyone participates).
  * alpha<1: the masked scan path matches the masked legacy loop (same
    on-device mask sequence from the policy state in the scan carry).
  * frozen clients really freeze: SCAFFOLD control variates and FedPD
    duals of masked-out clients are untouched.
  * client-sharded path: the masked `shard_map` round (mask entering with
    spec P('data'), masked psum aggregation) matches the single-device
    run (subprocess with 8 fake CPU devices).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fake_device_env
from repro.config import FedConfig
from repro.core import UniformParticipation, make_algorithm, run_rounds
from repro.core.selection import CyclicParticipation

M, N, D, ROUNDS, CHUNK = 8, 20, 400, 12, 5

# fedgia uses alpha=1.0 so the unmasked reference IS full participation
# (the engine mask replaces the in-algorithm draw, which would otherwise
# select a different subset from a different RNG stream)
ALGO_SETUPS = {
    "fedgia": dict(algorithm="fedgia", sigma_t=0.2, h_policy="scalar", alpha=1.0),
    "fedgia_diag": dict(algorithm="fedgia", sigma_t=0.2, h_policy="diag_ema",
                        alpha=1.0),
    "fedavg": dict(algorithm="fedavg", lr=0.01),
    "fedprox": dict(algorithm="fedprox", lr=0.002, prox_mu=1e-4, inner_steps=3),
    "fedpd": dict(algorithm="fedpd", lr=0.05, fedpd_eta=1.0, inner_steps=3),
    "scaffold": dict(algorithm="scaffold", lr=0.01),
}


@pytest.fixture(scope="module")
def problem():
    from repro.data import linreg_noniid
    from repro.models import LeastSquares

    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def _make(problem, key):
    model, batch = problem
    fed = FedConfig(num_clients=M, k0=3, **ALGO_SETUPS[key])
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                      init_batch=batch)
    return algo, state, batch


def _state_leaves(state):
    for k, v in state.items():
        for leaf in jax.tree.leaves(v):
            yield k, np.asarray(leaf)


@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
@pytest.mark.parametrize("scan", [True, False], ids=["scan", "legacy"])
def test_alpha1_mask_is_bitwise_identical(problem, algo_key, scan):
    """Dense all-True mask == no mask, bit for bit (history AND state)."""
    algo, state, batch = _make(problem, algo_key)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK)
    res = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK,
                     participation=UniformParticipation(M, 1.0, seed=9))
    assert res.rounds_run == ref.rounds_run
    for k in ref.history:
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=f"{algo_key}/{k}")
    np.testing.assert_array_equal(res.history["selected"], float(M))
    for (k, a), (_, b) in zip(_state_leaves(ref.state), _state_leaves(res.state)):
        np.testing.assert_array_equal(a, b, err_msg=f"{algo_key}/state[{k}]")


@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
def test_masked_scan_matches_legacy_loop(problem, algo_key):
    """alpha=0.5: identical mask sequence -> matching runs on both paths."""
    algo, state, batch = _make(problem, algo_key)
    pol = UniformParticipation(M, 0.5, seed=3)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     participation=pol)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=False, participation=pol)
    assert res.rounds_run == ref.rounds_run == ROUNDS
    assert set(res.history) == set(ref.history)
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    for (k, a), (_, b) in zip(_state_leaves(ref.state), _state_leaves(res.state)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                   err_msg=f"state[{k}]")
    # |C| = 4 of 8, every round
    np.testing.assert_array_equal(res.history["selected"], 4.0)


@pytest.mark.parametrize("algo_key,state_key",
                         [("scaffold", "ci"), ("fedpd", "lam")])
def test_frozen_clients_keep_local_state(problem, algo_key, state_key):
    """Masked-out clients must not move their per-client carry state."""
    algo, state, batch = _make(problem, algo_key)
    pol = UniformParticipation(M, 0.25, seed=1)
    mask0 = np.asarray(pol.mask(pol.init(), jnp.int32(0))[0])
    assert mask0.sum() == 2
    res = run_rounds(algo, state, batch, 1, scan=False, participation=pol)
    before = jax.tree.leaves(state[state_key])
    after = jax.tree.leaves(res.state[state_key])
    for b, a in zip(before, after):
        b, a = np.asarray(b), np.asarray(a)
        np.testing.assert_array_equal(a[~mask0], b[~mask0])
        # participants did move (update is nonzero on this problem)
        assert not np.allclose(a[mask0], b[mask0])


def test_server_state_ignores_frozen_clients(problem):
    """FedAvg aggregation over participants only: a round where client i is
    frozen must not read client i's local trajectory — replacing the frozen
    clients' batch data must not change the aggregate."""
    algo, state, batch = _make(problem, "fedavg")
    pol = CyclicParticipation(M, 0.5)  # round 0 freezes clients 4..7
    res = run_rounds(algo, state, batch, 1, scan=False, participation=pol)
    poisoned = {k: v.at[M // 2:].mul(100.0) for k, v in batch.items()}
    res2 = run_rounds(algo, state, poisoned, 1, scan=False, participation=pol)
    np.testing.assert_array_equal(np.asarray(res.state["x"]["x"]),
                                  np.asarray(res2.state["x"]["x"]))


def test_masked_early_stop_agrees(problem):
    """The eq.-35 device-side stopping rule composes with participation."""
    algo, state, batch = _make(problem, "fedgia")
    pol = UniformParticipation(M, 0.5, seed=0)
    ref = run_rounds(algo, state, batch, 300, tol=1e-7, scan=False,
                     participation=pol)
    res = run_rounds(algo, state, batch, 300, tol=1e-7, scan=True,
                     chunk_size=13, participation=pol)
    assert ref.stopped_early and res.stopped_early
    assert res.rounds_run == ref.rounds_run
    assert len(res.history["grad_sq_norm"]) == res.rounds_run


_SHARDED_MASKED_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import FedConfig
    from repro.core import UniformParticipation, make_algorithm, run_rounds
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    for algo_name, kw, mesh in (
        ("fedgia", dict(sigma_t=0.3, h_policy="diag_ema", alpha=1.0),
         make_host_mesh(data=8)),
        ("scaffold", dict(lr=0.01), make_host_mesh(model=2, data=4)),
    ):
        fed = FedConfig(algorithm=algo_name, num_clients=m, k0=5, **kw)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        pol = UniformParticipation(m, 0.5, seed=2)
        ref = run_rounds(algo, s0, batch, 10, scan=True, chunk_size=5,
                         participation=pol)
        res = run_rounds(algo, s0, batch, 10, scan=True, chunk_size=5,
                         participation=pol, mesh=mesh)
        # rtol 1e-4: the masked psum reduces per-shard partial sums in a
        # different order than the single-device sum, so fp32 drift over
        # 10 rounds is slightly larger than the unmasked engine's
        for k in ref.history:
            np.testing.assert_allclose(res.history[k], ref.history[k],
                                       rtol=1e-4, atol=1e-6,
                                       err_msg=f"{algo_name}/{k}")
        for key in ref.state:
            for a, b in zip(jax.tree.leaves(ref.state[key]),
                            jax.tree.leaves(res.state[key])):
                np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                           rtol=1e-4, atol=1e-6,
                                           err_msg=f"{algo_name}/{key}")
        assert list(res.history["selected"]) == [4.0] * 10
    print("MASKED_SHARDED_OK")
    """
)


def test_masked_sharded_matches_single_device():
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_MASKED_SCRIPT], env=fake_device_env(8),
        capture_output=True, text=True, timeout=600,
    )
    assert "MASKED_SHARDED_OK" in out.stdout, out.stdout + out.stderr
