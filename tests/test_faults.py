"""Fault-tolerant rounds (core/faults.py + the engine's guard rail).

Four contracts pinned here (docs/faults.md):

* STRUCTURE IS FREE — a fault model at rate 0 plus the screening stage
  leaves every algorithm's history and state BITWISE unchanged on every
  path (scan/legacy × dense/active/offload): injection corrupts values,
  never the program, and the screening finite-check rides eq. (11)'s
  existing collective (the sharded round still lowers to ONE model-size
  all-reduce / {1 RS, 1 AG} — subprocess HLO assertions below).
* DEFENSE WORKS — NaN injection with screening on converges (no
  non-finite value ever reaches the psum); without screening the run
  records the divergence honestly instead of masking it.
* DEGRADATION IS RECORDED — under-quorum rounds commit nothing but the
  round counter and flag `degraded`; the divergence watchdog restores
  the best-f̄ snapshot and flags `rollback`.
* RECOVERY IS BITWISE — a checkpointed run killed mid-way and resumed
  reproduces the uninterrupted run's history and final state exactly,
  for all five algorithms (scan driver) and the offload loop; resuming
  under a different config fingerprint raises.

Fault draws are stateless (`fold_in(seed, round)` over GLOBAL row ids),
so the same faults hit the same clients on every path and across resume.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fake_device_env
from repro.config import FedConfig
from repro.core import (
    Screening,
    make_algorithm,
    make_clock,
    make_faults,
    make_policy,
    run_rounds,
)
from repro.core import engine
from repro.core.faults import FaultModel, FaultSpec, screen_rows
from repro.data import linreg_noniid
from repro.models import LeastSquares
from repro.utils import pytree as pt

M, N, D = 8, 20, 400
ROUNDS = 8

ALGO_SETUPS = {
    "fedgia": dict(sigma_t=0.2, h_policy="diag_ema", alpha=0.5),
    "fedavg": dict(lr=0.01),
    "fedprox": dict(lr=0.002, prox_mu=1e-4, inner_steps=3),
    "fedpd": dict(lr=0.05, fedpd_eta=1.0, inner_steps=3),
    "scaffold": dict(lr=0.01),
}
FIVE = sorted(ALGO_SETUPS)


@pytest.fixture(scope="module")
def problem():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def _make(problem, key, **overrides):
    model, batch = problem
    kwargs = dict(algorithm=key, num_clients=M, k0=3)
    kwargs.update(ALGO_SETUPS[key])
    kwargs.update(overrides)
    fed = FedConfig(**kwargs)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    return algo, state


def _assert_bitwise(res, ref, *, ignore=("screened",)):
    """res must be bitwise ref, modulo metrics only res records."""
    assert res.rounds_run == ref.rounds_run
    assert set(res.history) - set(ref.history) <= set(ignore)
    for k in ref.history:
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), f"state[{key!r}] diverged"


# ------------------------------------------------ fault model unit layer
def test_fault_model_draw_is_stateless_and_rate_bounded():
    fm = make_faults(["crash"], [0.5], num_clients=64, seed=3)
    rows = jnp.arange(64)
    d0 = fm.draw(jnp.int32(7), rows)
    d1 = fm.draw(jnp.int32(7), rows)
    for a, b in zip(jax.tree.leaves(d0), jax.tree.leaves(d1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a disjoint round draws a different pattern (not a constant mask)
    d2 = fm.draw(jnp.int32(8), rows)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(d0), jax.tree.leaves(d2)))


def test_fault_model_row_split_matches_global_draw():
    """Per-client keys fold in GLOBAL row ids, so drawing for a slice of
    rows equals slicing the full draw — the property that makes faults
    identical across dense/active/offload tiles and shardings."""
    fm = make_faults(["crash", "nan"], [0.3], num_clients=32, seed=1)
    rows = jnp.arange(32)
    full = fm.draw(jnp.int32(4), rows)
    part = fm.draw(jnp.int32(4), rows[10:20])
    for kind in ("crash", "nan"):
        np.testing.assert_array_equal(np.asarray(full[kind])[10:20],
                                      np.asarray(part[kind]))


def test_make_faults_surface():
    assert make_faults([], [0.1], num_clients=4) is None
    fm = make_faults(["crash", "nan"], [0.1], num_clients=4)
    assert len(fm.specs) == 2 and all(s.rate == 0.1 for s in fm.specs)
    with pytest.raises(ValueError, match="--fault-rate"):
        make_faults(["crash", "nan", "inf"], [0.1, 0.2], num_clients=4)
    with pytest.raises(ValueError):
        FaultSpec("meteor", 0.1)
    with pytest.raises(ValueError):
        Screening(clip_norm=-1.0)
    assert FaultModel(num_clients=4,
                      specs=(FaultSpec("replay", 0.1),)).needs_prev


def test_screen_rows_drops_nonfinite_and_clips():
    contrib = jnp.asarray([[1.0, 2.0], [jnp.nan, 0.0], [30.0, 40.0],
                           [jnp.inf, 1.0]])
    mask = jnp.asarray([True, True, True, False])
    out, smask = screen_rows(contrib, mask, Screening(clip_norm=5.0))
    np.testing.assert_array_equal(np.asarray(smask),
                                  [True, False, True, False])
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out)[1], 0.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out)[2]), 5.0, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out)[0], [1.0, 2.0])


# ------------------------------- structure is free: rate-0 faults bitwise
@pytest.mark.parametrize("algo_key", FIVE)
def test_fault_free_rounds_bitwise_all_paths(problem, algo_key):
    """A rate-0 fault model leaves history AND state bitwise unchanged
    on scan, legacy, active and offload paths: injection corrupts
    values, never the trajectory."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    hard = dict(faults=make_faults(["crash", "nan"], [0.0],
                                   num_clients=M, seed=5))
    paths = [dict(), dict(scan=False),
             dict(store="active"), dict(store="offload")]
    for kw in paths:
        kw = dict(kw, participation=make_policy("uniform", M, 0.5, seed=3))
        ref = run_rounds(algo, state, batch, ROUNDS, **kw)
        res = run_rounds(algo, state, batch, ROUNDS, **kw, **hard)
        _assert_bitwise(res, ref)


@pytest.mark.parametrize("algo_key", FIVE)
def test_screening_benign_data_is_a_near_noop(problem, algo_key):
    """Screening on benign (all-finite) uploads: every count metric is
    bitwise the unscreened run's and the trajectory agrees to fp
    tolerance. (Exact bitwise is NOT claimed: the finite-check rider is
    a new op in the round graph, and XLA may re-fuse neighbouring
    arithmetic — observed as 1-ulp drift on CPU. The structural claim —
    bitwise — belongs to faults=None/screening=None, pinned above.)"""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    pol = make_policy("uniform", M, 0.5, seed=3)
    ref = run_rounds(algo, state, batch, ROUNDS, participation=pol)
    res = run_rounds(algo, state, batch, ROUNDS, participation=pol,
                     screening=Screening())
    assert res.rounds_run == ref.rounds_run
    for k in ("selected", "cr", "local_grad_evals"):
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=k)
    for k in ("f_xbar", "grad_sq_norm"):
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-5, atol=1e-7, err_msg=k)
    # nothing was screened out: FedGiA uploads the whole population's z
    # (screened mask starts from all m rows), the baselines upload the
    # participants only
    expect = (np.full(ROUNDS, float(M)) if algo_key == "fedgia"
              else ref.history["selected"])
    np.testing.assert_array_equal(res.history["screened"], expect)


def test_replay_faults_scan_matches_legacy(problem):
    """The replay fault carries last round's honest upload in the round
    state (`fault_prev`) — the stateful-est injection path must still be
    bitwise across scan/legacy."""
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    kw = dict(participation=make_policy("uniform", M, 0.5, seed=3),
              faults=make_faults(["replay"], [0.3], num_clients=M, seed=7),
              screening=Screening())
    ref = run_rounds(algo, state, batch, ROUNDS, **kw)
    res = run_rounds(algo, state, batch, ROUNDS, scan=False, **kw)
    for k in ref.history:
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=k)


# ------------------------------------------------- defense & degradation
def test_nan_injection_converges_with_screening(problem):
    algo, state = _make(problem, "fedgia")
    _, batch = problem
    res = run_rounds(algo, state, batch, 20,
                     participation=make_policy("uniform", M, 0.5, seed=3),
                     faults=make_faults(["nan", "inf"], [0.2],
                                        num_clients=M, seed=11),
                     screening=Screening())
    f = res.history["f_xbar"]
    assert np.all(np.isfinite(f))
    assert f[-1] < f[0]
    # screening visibly dropped uploads in at least one round
    assert (res.history["screened"] < res.history["selected"]).any()


def test_nan_injection_recorded_honestly_without_screening(problem):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    res = run_rounds(algo, state, batch, 12,
                     participation=make_policy("uniform", M, 0.5, seed=3),
                     faults=make_faults(["nan"], [0.5],
                                        num_clients=M, seed=11))
    assert not np.all(np.isfinite(res.history["f_xbar"]))


def test_quorum_degrades_rounds_to_recorded_noops(problem):
    algo, state = _make(problem, "scaffold")
    _, batch = problem
    res = run_rounds(algo, state, batch, 16,
                     participation=make_policy("uniform", M, 0.5, seed=3),
                     faults=make_faults(["crash"], [0.5],
                                        num_clients=M, seed=2),
                     screening=Screening(), quorum=2)
    deg = res.history["degraded"]
    assert deg.dtype == bool and deg.any() and not deg.all()
    assert np.all(np.isfinite(res.history["f_xbar"]))
    assert res.rounds_run == 16  # degraded rounds still advance the run


def test_watchdog_rolls_back_under_explosions(problem):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    res = run_rounds(algo, state, batch, 24,
                     participation=make_policy("uniform", M, 0.5, seed=3),
                     faults=make_faults(["explode"], [0.3],
                                        num_clients=M, seed=4),
                     watchdog=True, watchdog_patience=2)
    assert res.history["rollback"].sum() >= 1
    # the final state is a real (restored or surviving) state, not junk
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree.leaves(res.state["x"]))


def test_watchdog_quiet_run_never_fires(problem):
    algo, state = _make(problem, "fedgia")
    _, batch = problem
    kw = dict(participation=make_policy("uniform", M, 0.5, seed=3))
    ref = run_rounds(algo, state, batch, ROUNDS, **kw)
    res = run_rounds(algo, state, batch, ROUNDS, watchdog=True, **kw)
    assert res.history["rollback"].sum() == 0
    _assert_bitwise(res, ref, ignore=("rollback",))


def test_deadline_clock_rounds_advance_by_deadline(problem):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    clock = make_clock("constant", M,
                       compute_s=[1.0 + (i % 4) for i in range(M)],
                       deadline_s=2.5)
    res = run_rounds(algo, state, batch, ROUNDS, clock=clock, quorum=1)
    np.testing.assert_allclose(res.history["sim_time"],
                               2.5 * np.arange(1, ROUNDS + 1), rtol=1e-6)
    # the slow clients (3-4 s compute) miss their round's 2.5 s deadline
    # and re-arrive a LATER round: arrivals oscillate below/at m
    assert (res.history["selected"] < M).any()
    assert res.history["selected"].min() >= 1
    with pytest.raises(ValueError, match="quorum >= 1"):
        run_rounds(algo, state, batch, 2, clock=clock)


# ----------------------------------------------- engine validation layer
def test_engine_rejections(problem, tmp_path):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    pol = make_policy("uniform", M, 0.5, seed=3)
    with pytest.raises(ValueError, match="non-arrival"):
        run_rounds(algo, state, batch, 2, quorum=2)
    with pytest.raises(ValueError, match="quorum must be in"):
        run_rounds(algo, state, batch, 2, participation=pol, quorum=M + 1)
    with pytest.raises(ValueError, match="watchdog_patience"):
        run_rounds(algo, state, batch, 2, watchdog=True,
                   watchdog_patience=0)
    with pytest.raises(ValueError, match="watchdog_factor"):
        run_rounds(algo, state, batch, 2, watchdog=True,
                   watchdog_factor=1.0)
    with pytest.raises(ValueError, match="host-resident"):
        run_rounds(algo, state, batch, 2, participation=pol,
                   store="offload", watchdog=True)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        run_rounds(algo, state, batch, 2, checkpoint_every=1)
    with pytest.raises(ValueError, match="chunk"):
        run_rounds(algo, state, batch, 2, checkpoint_every=1,
                   checkpoint_dir=str(tmp_path), chunk_size="auto")
    with pytest.raises(ValueError, match="scan driver"):
        run_rounds(algo, state, batch, 2, scan=False, checkpoint_every=1,
                   checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="flat"):
        run_rounds(algo, state, batch, 2, flat=False,
                   faults=make_faults(["crash"], [0.1], num_clients=M))
    with pytest.raises(ValueError, match="clients"):
        run_rounds(algo, state, batch, 2,
                   faults=make_faults(["crash"], [0.1], num_clients=M + 1))


# --------------------------------------------- recovery: bitwise resume
@pytest.mark.parametrize("algo_key", FIVE)
def test_checkpoint_resume_bitwise_scan(problem, algo_key, tmp_path):
    """Kill at round 6 of 12 (checkpoints every 4), resume to 12: the
    resumed run's history and final state are BITWISE the uninterrupted
    run's — with faults on, so the stateless draws line up across the
    restart too."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    kw = dict(participation=make_policy("uniform", M, 0.5, seed=3),
              faults=make_faults(["crash", "explode"], [0.2],
                                 num_clients=M, seed=9),
              screening=Screening(clip_norm=1e3))
    ref = run_rounds(algo, state, batch, 12, **kw)
    d = str(tmp_path / algo_key)
    run_rounds(algo, state, batch, 6, checkpoint_every=4,
               checkpoint_dir=d, **kw)
    res = run_rounds(algo, state, batch, 12, checkpoint_every=4,
                     checkpoint_dir=d, resume=True, **kw)
    _assert_bitwise(res, ref, ignore=())


def test_checkpoint_resume_bitwise_offload(problem, tmp_path):
    algo, state = _make(problem, "scaffold")
    _, batch = problem
    kw = dict(participation=make_policy("uniform", M, 0.5, seed=3),
              store="offload", quorum=1,
              faults=make_faults(["crash"], [0.3], num_clients=M, seed=9),
              screening=Screening())
    ref = run_rounds(algo, state, batch, 12, **kw)
    d = str(tmp_path / "offload")
    run_rounds(algo, state, batch, 6, checkpoint_every=4,
               checkpoint_dir=d, **kw)
    res = run_rounds(algo, state, batch, 12, checkpoint_every=4,
                     checkpoint_dir=d, resume=True, **kw)
    _assert_bitwise(res, ref, ignore=())


def test_resume_rejects_fingerprint_mismatch(problem, tmp_path):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    pol = make_policy("uniform", M, 0.5, seed=3)
    d = str(tmp_path / "fp")
    run_rounds(algo, state, batch, 4, participation=pol,
               checkpoint_every=2, checkpoint_dir=d)
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        run_rounds(algo, state, batch, 8, participation=pol, quorum=2,
                   checkpoint_every=2, checkpoint_dir=d, resume=True)
    # extending num_rounds is NOT a mismatch — that is the point
    res = run_rounds(algo, state, batch, 8, participation=pol,
                     checkpoint_every=2, checkpoint_dir=d, resume=True)
    assert res.rounds_run == 8


def test_resume_without_checkpoint_is_fresh_start(problem, tmp_path):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    pol = make_policy("uniform", M, 0.5, seed=3)
    ref = run_rounds(algo, state, batch, ROUNDS, participation=pol)
    res = run_rounds(algo, state, batch, ROUNDS, participation=pol,
                     checkpoint_every=4, resume=True,
                     checkpoint_dir=str(tmp_path / "empty"))
    _assert_bitwise(res, ref, ignore=())


# ---------------------------------- legacy-loop donation (and its proof)
def test_legacy_donated_rounds_bitwise(problem):
    """donate=True on the legacy loop (AOT + donated state/anchor/
    watchdog args) is bitwise the undonated loop."""
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    kw = dict(participation=make_policy("uniform", M, 0.5, seed=3),
              scan=False, watchdog=True)
    ref = run_rounds(algo, state, batch, ROUNDS, donate=False, **kw)
    res = run_rounds(algo, state, batch, ROUNDS, donate=True, **kw)
    _assert_bitwise(res, ref, ignore=())


@pytest.mark.parametrize("algo_key", ["fedavg", "scaffold"])
def test_legacy_donation_no_model_size_temp_growth(problem, algo_key):
    """`memory_analysis` proof for the baselines' flat GD rounds:
    off-CPU the donated lowering allocates no more temp than the
    undonated one and aliases at least the (m, N) client state onto
    outputs. On CPU, XLA cannot alias — donation is a no-op there and
    the annotation alone perturbs fusion/temp bytes by a few KB — so
    on CPU this is a compile smoke only (the donated loop's numerics
    are covered by test_legacy_donated_rounds_bitwise)."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    spec = pt.ravel_spec(state["x"])
    fstate = engine.flatten_state(algo, state, spec)
    rf = engine.make_round_fn(algo, flat_spec=spec)
    don = jax.jit(rf, donate_argnums=(0,)).lower(
        fstate, batch).compile().memory_analysis()
    und = jax.jit(rf).lower(fstate, batch).compile().memory_analysis()
    if jax.default_backend() != "cpu":
        assert don.temp_size_in_bytes <= und.temp_size_in_bytes
        client_bytes = sum(
            int(np.asarray(fstate[k]).nbytes)
            for k in getattr(algo, "flat_client_keys", ()) if k in fstate)
        assert don.alias_size_in_bytes >= client_bytes


# ------------------------- hardened host transfers (utils/pytree.py)
def test_host_put_retries_then_demotes_to_cpu(monkeypatch):
    calls = {"n": 0}
    orig = jax.device_put

    def flaky(x, device=None, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("simulated pinned-host exhaustion")
        return orig(x, device, **kw)

    monkeypatch.setattr(jax, "device_put", flaky)
    with pytest.warns(RuntimeWarning, match="retrying once"):
        out = pt.host_put(jnp.ones((3,)))
    np.testing.assert_array_equal(np.asarray(out), 1.0)
    monkeypatch.setattr(jax, "device_put", orig)

    # both attempts failing on a pinned-host SHARDING demotes the
    # process-wide placement to the CPU device instead of crashing
    monkeypatch.setattr(
        pt, "_HOST_PLACEMENT",
        jax.sharding.SingleDeviceSharding(jax.devices()[0]))

    def dead(x, device=None, **kw):
        if isinstance(device, jax.sharding.Sharding):
            raise RuntimeError("simulated dead DMA path")
        return orig(x, device, **kw)

    monkeypatch.setattr(jax, "device_put", dead)
    with pytest.warns(RuntimeWarning, match="falling back to the CPU"):
        out = pt.host_put(jnp.ones((2,)))
    np.testing.assert_array_equal(np.asarray(out), 1.0)
    assert not isinstance(pt._HOST_PLACEMENT, jax.sharding.Sharding)
    monkeypatch.setattr(pt, "_HOST_PLACEMENT", None)


# ----------------------- sharded: screening rides the ONE collective
_SHARDED_FAULT_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp
    from hlo_guard import assert_barrier_round, assert_overlap_round
    from repro.config import FedConfig
    from repro.core import engine, make_algorithm, make_faults, Screening
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares
    from repro.utils import pytree as pt

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)
    hard = dict(faults=make_faults(["crash", "nan"], [0.1],
                                   num_clients=m, seed=1),
                screening=Screening(clip_norm=100.0))

    def round_hlo(algo_name, **kw):
        fed = FedConfig(algorithm=algo_name, num_clients=m, k0=3, alpha=0.5,
                        sigma_t=0.3, h_policy="diag_ema", lr=0.01)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        spec = pt.ravel_spec(s0["x"])
        s0f = engine.flatten_state(algo, s0, spec)
        if kw.get("overlap"):
            rows = int(getattr(algo, "overlap_slot_rows", 1))
            s0f["ovl_shard"] = jnp.zeros((rows, spec.padded_size),
                                         s0f["x"].dtype)
        rf = engine.make_round_fn(algo, mesh, masked=True, flat_spec=spec,
                                  **hard, **kw)
        st, b = engine.shard_inputs(algo, s0f, batch, mesh)
        return jax.jit(rf).lower(st, b, jnp.ones((m,), bool)
                                 ).compile().as_text()

    for name in ("fedgia", "fedavg", "fedprox", "fedpd", "scaffold"):
        assert_barrier_round(round_hlo(name), name)
    assert_overlap_round(round_hlo("fedgia", overlap="scatter"), "overlap")
    print("FAULT_SHARDED_OK screening rides the one collective")
    """
)


def test_sharded_screening_keeps_one_collective():
    """With faults + screening threaded in, the sharded round still
    lowers to exactly ONE model-size all-reduce (barrier) for all five
    algorithms, and the overlapped FedGiA round to {1 RS, 1 AG} — the
    finite-check/clip/count are riders on the existing collectives."""
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_FAULT_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=900,
    )
    assert "FAULT_SHARDED_OK" in out.stdout, out.stdout + out.stderr
