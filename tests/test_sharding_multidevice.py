"""Distribution correctness: a sharded FedGiA round on a (fake) 8-device
mesh must produce numerically identical results to the single-device run,
and the spec factories must produce divisibility-valid shardings.

Fake devices are created per-subprocess via `conftest.fake_device_env`
(XLA_FLAGS must be set before jax import, so the checks run out of
process; the parent suite keeps its single real CPU device)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from conftest import fake_device_env

_MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config import FedConfig
    from repro.core import make_algorithm
    from repro.data import linreg_noniid
    from repro.models import LeastSquares
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import fed_state_specs, train_batch_specs, sanitize_specs

    m, n, d = 4, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    fed = FedConfig(algorithm="fedgia", num_clients=m, k0=5, alpha=1.0,
                    sigma_t=0.3, h_policy="scalar", client_axes=("data",))
    algo = make_algorithm(fed, model.loss, model=model)
    state0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                       init_batch=batch)

    # single-device reference
    ref_state = state0
    for _ in range(5):
        ref_state, ref_met = algo.round(ref_state, batch)

    # sharded run on (data=4, model=2)
    mesh = make_host_mesh(model=2, data=4)
    sspec = sanitize_specs(fed_state_specs(fed, None, jax.eval_shape(lambda: state0)),
                           jax.eval_shape(lambda: state0), mesh)
    bspec = sanitize_specs(
        train_batch_specs(fed, jax.eval_shape(lambda: batch), mesh.axis_names),
        jax.eval_shape(lambda: batch), mesh)
    shard = lambda sp: jax.tree.map(lambda s: NamedSharding(mesh, s), sp)
    state = jax.device_put(state0, shard(sspec))
    b = jax.device_put(batch, shard(bspec))
    step = jax.jit(algo.round, in_shardings=(shard(sspec), shard(bspec)),
                   out_shardings=None)
    for _ in range(5):
        state, met = step(state, b)
    np.testing.assert_allclose(np.asarray(state["x"]["x"]),
                               np.asarray(ref_state["x"]["x"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(met["f_xbar"]), float(ref_met["f_xbar"]),
                               rtol=1e-5)
    print("MULTIDEV_OK")
    """
)

# engine client-sharded path: shard_map over the mesh's data axis must be
# allclose to the single-device scan for FedGiA under both H policies.
_ENGINE_SHARDED_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import FedConfig
    from repro.core import make_algorithm, run_rounds
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    for h_policy, mesh in (("scalar", make_host_mesh(data=8)),
                           ("diag_ema", make_host_mesh(model=2, data=4))):
        fed = FedConfig(algorithm="fedgia", num_clients=m, k0=5, alpha=0.5,
                        sigma_t=0.3, h_policy=h_policy)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        ref = run_rounds(algo, s0, batch, 10, scan=True, chunk_size=5)
        res = run_rounds(algo, s0, batch, 10, scan=True, chunk_size=5,
                         mesh=mesh)
        for key in ("x", "z", "pi"):
            np.testing.assert_allclose(np.asarray(res.state[key]["x"]),
                                       np.asarray(ref.state[key]["x"]),
                                       rtol=1e-5, atol=1e-6, err_msg=h_policy)
        for key in ref.history:
            np.testing.assert_allclose(res.history[key], ref.history[key],
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"{h_policy}/{key}")
    print("ENGINE_SHARDED_OK")
    """
)


def _run_fake8(script: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", script], env=fake_device_env(8),
        capture_output=True, text=True, timeout=600,
    )


def test_sharded_round_matches_single_device():
    out = _run_fake8(_MULTIDEV_SCRIPT)
    assert "MULTIDEV_OK" in out.stdout, out.stdout + out.stderr


def test_engine_client_sharded_matches_single_device():
    out = _run_fake8(_ENGINE_SHARDED_SCRIPT)
    assert "ENGINE_SHARDED_OK" in out.stdout, out.stdout + out.stderr


def test_sanitize_drops_nondivisible_axes():
    from repro.sharding import sanitize_specs

    import jax.numpy as jnp

    specs = {"a": P(None, "model"), "b": P("model")}
    shapes = {
        "a": jax.ShapeDtypeStruct((4, 40), jnp.float32),
        "b": jax.ShapeDtypeStruct((7,), jnp.float32),
    }

    class FakeMesh:
        axis_names = ("model",)

        class devices:
            shape = (16,)

    fixed = sanitize_specs(specs, shapes, FakeMesh())
    assert fixed["a"] == P(None, None)  # 40 % 16 != 0 -> dropped
    assert fixed["b"] == P(None)


def test_param_specs_shard_big_leaves():
    """Spec factory: big matmul weights get a model-axis assignment."""
    import jax.numpy as jnp

    from repro.configs import ARCHITECTURES
    from repro.models import Transformer
    from repro.sharding import param_specs

    cfg = ARCHITECTURES["tinyllama-1.1b"]
    model = Transformer(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(cfg, sds)
    flat = jax.tree_util.tree_flatten_with_path((specs, sds))
    wq_spec = specs["groups"]["dense"]["attn"]["wq"]
    assert "model" in str(wq_spec)
    w2_spec = specs["groups"]["dense"]["mlp"]["w2"]
    assert w2_spec[1] == "model"  # input dim sharded (scan dim first)
    assert specs["final_norm"]["scale"] == P()
