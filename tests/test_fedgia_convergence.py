"""Convergence-theory tests: the paper's Lemma IV.1, Theorems IV.1-IV.4."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig
from repro.core import make_algorithm
from repro.data import linreg_noniid, logreg_data
from repro.models import LeastSquares, LogisticRegression, NonConvexLogistic

M, N, D = 8, 30, 640


def centralized_optimum(batch, m):
    A = np.asarray(batch["A"]); b = np.asarray(batch["b"]); msk = np.asarray(batch["mask"])
    rows, w = [], []
    for i in range(m):
        di = msk[i].sum()
        rows.append(A[i][msk[i] > 0])
        w.append(np.full(int(di), 1.0 / (m * di)))
    A_, w_ = np.concatenate(rows), np.concatenate(w)
    b_ = np.concatenate([b[i][msk[i] > 0] for i in range(m)])
    H = (A_ * w_[:, None]).T @ A_
    g = (A_ * w_[:, None]).T @ b_
    x = np.linalg.solve(H, g)
    f = 0.5 * float(np.sum(w_ * (A_ @ x - b_) ** 2))
    return x, f


def run(model, batch, rounds=400, tol=1e-11, **kw):
    defaults = dict(algorithm="fedgia", num_clients=M, k0=5, alpha=0.5,
                    sigma_t=0.2, h_policy="scalar", collapsed=True)
    defaults.update(kw)
    fed = FedConfig(**defaults)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(7),
                      init_batch=batch)
    rnd = jax.jit(algo.round)
    hist = []
    for _ in range(rounds):
        state, met = rnd(state, batch)
        hist.append((float(met["f_xbar"]), float(met["grad_sq_norm"])))
        if hist[-1][1] < tol:
            break
    return algo, state, hist


@pytest.fixture(scope="module")
def linreg():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(3, D, N, M).items()}
    return LeastSquares(N), batch


def test_converges_to_centralized_optimum(linreg):
    """Corollary IV.1: strongly-convex f -> x̄ -> unique optimum x*."""
    model, batch = linreg
    x_star, f_star = centralized_optimum(batch, M)
    algo, state, hist = run(model, batch)
    assert hist[-1][1] < 1e-10, f"no stationarity: {hist[-1]}"
    np.testing.assert_allclose(np.asarray(state["x"]["x"]), x_star, rtol=1e-3, atol=1e-4)
    assert abs(hist[-1][0] - f_star) < 1e-6


def test_lagrangian_descent(linreg):
    """Lemma IV.1: with sigma >= 6r/m and H=Theta, L(Z^k) is non-increasing."""
    model, batch = linreg
    fed = FedConfig(algorithm="fedgia", num_clients=M, k0=5, alpha=0.5,
                    sigma_t=6.0, h_policy="scalar", collapsed=True)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(7),
                      init_batch=batch)
    rnd = jax.jit(algo.round)
    lag = jax.jit(algo.lagrangian)
    prev = float(lag(state, batch))
    for _ in range(30):
        state, _ = rnd(state, batch)
        cur = float(lag(state, batch))
        assert cur <= prev + 1e-6, f"Lagrangian increased: {prev} -> {cur}"
        prev = cur


def test_theorem_iv3_rate_bound(linreg):
    """min_j |grad f(x^tau_j)|^2 <= 100 m sigma k0 (L(Z^0) - f*) / k."""
    model, batch = linreg
    _, f_star = centralized_optimum(batch, M)
    k0 = 5
    fed = FedConfig(algorithm="fedgia", num_clients=M, k0=k0, alpha=0.5,
                    sigma_t=6.0, h_policy="scalar", collapsed=True)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(7),
                      init_batch=batch)
    L0 = float(algo.lagrangian(state, batch))
    sigma = float(state["sigma"])
    rnd = jax.jit(algo.round)
    best = np.inf
    for t in range(40):
        state, met = rnd(state, batch)
        best = min(best, float(met["grad_sq_norm"]))
        k = (t + 1) * k0
        bound = 100 * M * sigma * k0 * max(L0 - f_star, 0.0) / k
        assert best <= bound + 1e-8, f"rate bound violated at k={k}"


def test_linear_rate_strongly_convex(linreg):
    """Theorem IV.4 (theta=1/2): geometric decay of f(x̄) - f*."""
    model, batch = linreg
    _, f_star = centralized_optimum(batch, M)
    _, _, hist = run(model, batch, rounds=100, tol=0.0, alpha=1.0)
    gaps = np.array([max(f - f_star, 1e-16) for f, _ in hist])
    # fit log-gap slope over the first decades; must be clearly negative
    idx = np.flatnonzero(gaps > 1e-12)[:40]
    slope = np.polyfit(idx, np.log(gaps[idx]), 1)[0]
    assert slope < -0.05, f"no linear decay, slope={slope}"


def test_logreg_and_nonconvex_converge():
    """Theorem IV.2: stationarity for the convex AND non-convex examples."""
    batch = {k: jnp.asarray(v) for k, v in logreg_data(1, D, N, M).items()}
    for model in (LogisticRegression(N), NonConvexLogistic(N)):
        algo, state, hist = run(model, batch, rounds=600, tol=1e-9, sigma_t=0.3)
        assert hist[-1][1] < 1e-8, f"{type(model).__name__}: {hist[-1]}"


def test_effect_of_k0_monotone_iterations(linreg):
    """Fig. 1: larger k0 needs >= as many ITERATIONS (k = rounds*k0) but
    FEWER or equal communication rounds to a fixed tolerance."""
    model, batch = linreg
    rounds_used = {}
    for k0 in (1, 5, 15):
        _, _, hist = run(model, batch, rounds=600, tol=1e-9, k0=k0)
        rounds_used[k0] = len(hist)
    assert rounds_used[5] <= rounds_used[1]
    assert rounds_used[15] <= rounds_used[1]
