"""Active-set client store equivalence (engine `store="active"`).

The active store swaps the dense (m, N) round working set for a packed
(capacity, N) tile of the round's participants: gathered from the
resident flat buffers at the round start, scattered back at the end.
STATE must be BITWISE the dense store's on every single-device path —
the tile rows are the same trajectories (row-position-independent math)
and the aggregation scatters back to the dense layout before reducing,
so eq. (11) sees bit-identical inputs through the same compiled reduce
(api.flat_round_aggregate_active). The loss/gradient DIAGNOSTICS differ
by construction: the server never contacts frozen clients, so `f_xbar`
and `grad_sq_norm` become participant means (docs/engine.md). FedGiA
declares `active_tile="population"` (its GD branch rewrites every
client every round) and falls back to the dense round — for it the
whole history is bitwise too.

Also covers: ActiveSet packing/gather/scatter units, the engine's
store validation, auto-chunk composition, and (subprocess) the zero-tail
debug assertion (REPRO_DEBUG_TAIL=1) plus the sharded active round's
ONE model-size all-reduce.

The HOST-OFFLOADED store (`store="offload"`) moves the resident client
buffers + batch + stale anchor into host memory and shuttles (capacity,
N) tiles per round; host gather/scatter is pure data movement, so it
must be BITWISE `store="active"` on every path — including the full
metric history (same tile bits through same-shaped reductions). The
PACKED aggregation (`aggregate="packed"`) sums the participant tile
directly and is held to fp tolerance against the dense layout, with the
sharded packed round still lowering to ONE model-size all-reduce.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fake_device_env
from repro.config import FedConfig
from repro.core import make_algorithm, make_policy, run_rounds
from repro.core.clock import ComputeClock
from repro.data import linreg_noniid
from repro.models import LeastSquares
from repro.utils import pytree as pt

M, N, D = 8, 20, 400
ROUNDS = 10

ALGO_SETUPS = {
    "fedgia": dict(sigma_t=0.2, h_policy="diag_ema", alpha=0.5),
    "fedavg": dict(lr=0.01),
    "fedprox": dict(lr=0.002, prox_mu=1e-4, inner_steps=3),
    "fedpd": dict(lr=0.05, fedpd_eta=1.0, inner_steps=3),
    "scaffold": dict(lr=0.01),
}
FIVE = sorted(ALGO_SETUPS)

# metrics that must match bitwise between stores for EVERY algorithm;
# f_xbar / grad_sq_norm are participant means under the active store and
# only match for population-tile algorithms (fedgia)
COMPARABLE = ("selected", "cr", "local_grad_evals", "staleness",
              "staleness_max", "sim_time")


@pytest.fixture(scope="module")
def problem():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def _make(problem, key, **overrides):
    model, batch = problem
    kwargs = dict(algorithm=key, num_clients=M, k0=3)
    kwargs.update(ALGO_SETUPS[key])
    kwargs.update(overrides)
    fed = FedConfig(**kwargs)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    return algo, state


def _assert_store_equiv(res, ref, algo):
    """Active (res) vs dense (ref): bitwise state, bitwise comparable
    metrics; the full history bitwise for population-tile algorithms."""
    assert res.rounds_run == ref.rounds_run
    assert set(res.history) == set(ref.history)
    full = getattr(algo, "active_tile", "participants") == "population"
    for k in ref.history:
        if full or k in COMPARABLE:
            np.testing.assert_array_equal(res.history[k], ref.history[k],
                                          err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), f"state[{key!r}] diverged"


def _run_pair(algo, state, batch, **kw):
    ref = run_rounds(algo, state, batch, ROUNDS, store="dense", **kw)
    res = run_rounds(algo, state, batch, ROUNDS, store="active", **kw)
    return res, ref


# ------------------------------------------------- ActiveSet pack/gather
def test_make_active_set_packs_ascending_with_sentinel_padding():
    mask = jnp.asarray([0, 1, 0, 1, 1, 0, 0, 1], bool)
    aset = pt.make_active_set(mask, capacity=6)
    np.testing.assert_array_equal(np.asarray(aset.idx),
                                  [1, 3, 4, 7, 8, 8])  # sentinel = m
    np.testing.assert_array_equal(np.asarray(aset.valid),
                                  [1, 1, 1, 1, 0, 0])
    assert float(aset.count) == 4.0
    assert aset.capacity == 6 and aset.num_clients == 8


def test_gather_scatter_roundtrip_leaves_frozen_rows():
    mask = jnp.asarray([0, 1, 0, 1], bool)
    aset = pt.make_active_set(mask, capacity=2)
    buf = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    tile = aset.gather(buf)
    np.testing.assert_array_equal(np.asarray(tile), np.asarray(buf)[[1, 3]])
    out = aset.scatter(buf, tile * 10.0)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(buf[0]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(buf[2]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(buf[1]) * 10)
    # padding rows (sentinel index) are DROPPED at the scatter
    aset1 = pt.make_active_set(jnp.asarray([0, 1, 0, 0], bool), capacity=3)
    out = aset1.scatter(buf, jnp.full((3, 3), -1.0))
    np.testing.assert_array_equal(np.asarray(out[1]), -np.ones(3))
    for r in (0, 2, 3):
        np.testing.assert_array_equal(np.asarray(out[r]), np.asarray(buf[r]))


def test_zero_invalid_zeroes_padding_rows_only():
    aset = pt.make_active_set(jnp.asarray([1, 0, 0, 1], bool), capacity=3)
    tile = jnp.ones((3, 5))
    z = np.asarray(aset.zero_invalid(tile))
    np.testing.assert_array_equal(z[:2], np.ones((2, 5)))
    np.testing.assert_array_equal(z[2], np.zeros(5))


def test_policy_active_capacity_and_indices():
    pol = make_policy("uniform", M, 0.5, seed=0)
    assert pol.active_capacity == pol.n_selected == M // 2
    aset, _ = pol.indices(pol.init(), 0)
    assert aset.capacity == M // 2
    assert bool(jnp.all(aset.valid))  # uniform fills the tile exactly
    # variable-cardinality policies bound the tile by m
    strag = make_policy("straggler", M, seed=0, drop_prob=0.3, horizon=8)
    assert strag.active_capacity == M


# ------------------------------------------------ active == dense, masked
@pytest.mark.parametrize("algo_key", FIVE)
def test_active_matches_dense_masked_scan(problem, algo_key):
    algo, state = _make(problem, algo_key)
    _, batch = problem
    res, ref = _run_pair(algo, state, batch,
                         participation=make_policy("uniform", M, 0.5, seed=3))
    _assert_store_equiv(res, ref, algo)


@pytest.mark.parametrize("algo_key", FIVE)
def test_active_matches_dense_masked_legacy(problem, algo_key):
    algo, state = _make(problem, algo_key)
    _, batch = problem
    res, ref = _run_pair(algo, state, batch, scan=False,
                         participation=make_policy("uniform", M, 0.5, seed=3))
    _assert_store_equiv(res, ref, algo)


@pytest.mark.parametrize("kind", ["cyclic", "weighted", "straggler"])
def test_active_matches_dense_other_policies(problem, kind):
    """Fixed-cardinality tiles (cyclic/weighted) and the variable-
    cardinality m-bound tile (straggler) all stay bitwise."""
    algo, state = _make(problem, "scaffold")
    _, batch = problem
    res, ref = _run_pair(
        algo, state, batch,
        participation=make_policy(kind, M, 0.5, seed=1, drop_prob=0.3,
                                  horizon=ROUNDS))
    _assert_store_equiv(res, ref, algo)


# --------------------------------------------------- async / clocked paths
@pytest.mark.parametrize("algo_key", ["fedavg", "scaffold", "fedgia"])
def test_active_matches_dense_async(problem, algo_key):
    """Stale-x̄ rounds: ages stay dense (m,) scalars, the anchor tile is
    gathered with force-refresh, and the resident anchor buffer takes one
    dense row-select per round — bitwise the dense async engine,
    including the per-round `staleness` history."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    pol = make_policy("periodic", M)
    res, ref = _run_pair(algo, state, batch, participation=pol,
                         async_rounds=True, max_staleness=2)
    _assert_store_equiv(res, ref, algo)


def test_active_matches_dense_async_zero_staleness(problem):
    algo, state = _make(problem, "fedpd")
    _, batch = problem
    res, ref = _run_pair(algo, state, batch,
                         participation=make_policy("periodic", M),
                         async_rounds=True, max_staleness=0)
    _assert_store_equiv(res, ref, algo)


@pytest.mark.parametrize("algo_key", ["fedavg", "scaffold"])
def test_active_matches_dense_clocked_weighted(problem, algo_key):
    """Wall-clock arrivals (tile capacity = m) with staleness-weighted
    aggregation: the dense weights enter the aggregate as the same
    masked (m,) vector, so the weighted eq. (11) stays bitwise."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    clk = ComputeClock(M, 1.0 + (np.arange(M) % 3))
    res, ref = _run_pair(algo, state, batch, clock=clk, max_staleness=3,
                         stale_weighting="poly", stale_decay=0.5)
    _assert_store_equiv(res, ref, algo)


# --------------------------------------------------- engine knob composure
def test_active_chunk_auto_matches_fixed(problem):
    """`--chunk auto` composes with the active store: the tile
    gather/scatter runs inside every round whatever the chunk length, so
    the autotuned run is bitwise the fixed-chunk active run."""
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    pol = lambda: make_policy("uniform", M, 0.5, seed=3)
    ref = run_rounds(algo, state, batch, 60, chunk_size=7,
                     participation=pol(), store="active")
    res = run_rounds(algo, state, batch, 60, chunk_size="auto",
                     participation=pol(), store="active")
    assert res.rounds_run == ref.rounds_run == 60
    for k in ref.history:
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=k)


def test_active_early_stop_scan_matches_legacy(problem):
    """Under the active store the tol rule gates on the PARTICIPANT
    gradient norm (the population one is unobservable) — scan and legacy
    still stop on the same round with the same state."""
    algo, state = _make(problem, "fedgia", k0=5)
    _, batch = problem
    kw = dict(tol=1e-9, participation=make_policy("uniform", M, 0.5, seed=3),
              store="active")
    ref = run_rounds(algo, state, batch, 300, chunk_size=13, scan=False, **kw)
    res = run_rounds(algo, state, batch, 300, chunk_size=13, **kw)
    assert ref.stopped_early and res.stopped_early
    assert res.rounds_run == ref.rounds_run
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), key


def test_store_validation(problem):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    pol = make_policy("uniform", M, 0.5, seed=0)
    with pytest.raises(ValueError, match="unknown store"):
        run_rounds(algo, state, batch, 2, store="sparse", participation=pol)
    with pytest.raises(ValueError, match="flat"):
        run_rounds(algo, state, batch, 2, store="active", participation=pol,
                   flat=False)
    with pytest.raises(ValueError, match="participant"):
        run_rounds(algo, state, batch, 2, store="active")


# --------------------------------------------- zero-tail debug assertion
_TAIL_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp
    from repro.utils import pytree as pt
    assert pt.DEBUG_TAIL, "REPRO_DEBUG_TAIL not picked up"
    tree = {"w": jnp.ones((5,)), "b": jnp.zeros(())}
    spec = pt.ravel_spec(tree)
    assert spec.padded_size > spec.size  # lane padding present
    flat = spec.ravel(tree)
    spec.unravel(flat)  # clean tail passes
    jax.block_until_ready(jax.tree.leaves(spec.unravel(flat)))
    print("CLEAN_OK")
    bad = flat.at[spec.padded_size - 1].set(3.0)  # corrupt the pad lane
    try:
        jax.block_until_ready(jax.tree.leaves(spec.unravel(bad)))
        print("CORRUPTION_MISSED")
    except Exception:
        print("CORRUPTION_CAUGHT")
    """
)


def test_debug_tail_assertion_catches_corruption():
    """REPRO_DEBUG_TAIL=1 turns every unravel into a zero-tail audit: a
    clean flat buffer passes, a corrupted pad lane raises. Subprocess —
    the flag is read at import and must not leak into this session."""
    import os

    env = dict(os.environ, REPRO_DEBUG_TAIL="1")
    env.setdefault("PYTHONPATH", "src")
    out = subprocess.run(
        [sys.executable, "-c", _TAIL_SCRIPT], env=env,
        capture_output=True, text=True, timeout=300,
    )
    assert "CLEAN_OK" in out.stdout, out.stdout + out.stderr
    assert "CORRUPTION_CAUGHT" in out.stdout, out.stdout + out.stderr


def test_round_flat_active_keeps_zero_tail(problem):
    """The scatter path preserves the RavelSpec zero-tail invariant: after
    active rounds every resident flat buffer still has an exactly-zero
    pad tail (gathered tiles inherit it, local steps keep padded lanes at
    +0.0, and the scatter writes only participant rows)."""
    from repro.core.engine import flatten_state

    algo, state = _make(problem, "scaffold")
    _, batch = problem
    res = run_rounds(algo, state, batch, ROUNDS, store="active",
                     participation=make_policy("uniform", M, 0.5, seed=3))
    spec = pt.ravel_spec(state["x"])
    flat = flatten_state(algo, res.state, spec)
    for k in ("x", "c"):
        assert float(jnp.abs(flat[k][spec.size:]).max()) == 0.0, k
    assert float(jnp.abs(flat["ci"][:, spec.size:]).max()) == 0.0


# ------------------------------------- sharded: ONE model-size all-reduce
_SHARDED_ACTIVE_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from hlo_guard import assert_barrier_round
    from repro.config import FedConfig
    from repro.core import api, engine, make_algorithm, make_policy, run_rounds
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares
    from repro.utils import pytree as pt

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)

    def round_hlo(algo_name):
        fed = FedConfig(algorithm=algo_name, num_clients=m, k0=3, alpha=0.5,
                        sigma_t=0.3, h_policy="diag_ema", lr=0.01)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        spec = pt.ravel_spec(s0["x"])
        s0f = engine.flatten_state(algo, s0, spec)
        cap = make_policy("uniform", m, 0.5).active_capacity
        rf = engine.make_round_fn(algo, mesh, masked=True, flat_spec=spec,
                                  active_capacity=cap)
        st, b = engine.shard_inputs(algo, s0f, batch, mesh)
        return jax.jit(rf).lower(st, b, jnp.ones((m,), bool)
                                 ).compile().as_text()

    for name in ("fedgia", "fedavg", "fedprox", "fedpd", "scaffold"):
        assert_barrier_round(round_hlo(name), name)

    # and the sharded active RUN matches the single-device active run
    fed = FedConfig(algorithm="scaffold", num_clients=m, k0=3, lr=0.01)
    algo = make_algorithm(fed, model.loss, model=model)
    s0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                   init_batch=batch)
    kw = dict(participation=make_policy("uniform", m, 0.5, seed=3),
              store="active")
    ref = run_rounds(algo, s0, batch, 10, **kw)
    res = run_rounds(algo, s0, batch, 10, mesh=mesh, **kw)
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-4, atol=1e-6, err_msg=k)
    print("ACTIVE_SHARDED_OK one model-size all-reduce for all five")
    """
)


def test_active_sharded_one_all_reduce_and_parity():
    """The sharded active round packs per shard (capacity clamped to
    m_local) and still lowers to exactly ONE model-size all-reduce for
    all five algorithms; the sharded active run matches the single-device
    active run to fp tolerance."""
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_ACTIVE_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=900,
    )
    assert "ACTIVE_SHARDED_OK" in out.stdout, out.stdout + out.stderr


# ------------------------------------------- offload == active, bitwise
def _assert_offload_equiv(res, ref):
    """Offload (res) vs active (ref): bitwise state AND bitwise full
    history — host gather/scatter is pure data movement, so every tile
    entering the round carries the active store's exact bits and every
    metric leaves through the same-shaped reductions."""
    assert res.rounds_run == ref.rounds_run
    assert set(res.history) == set(ref.history)
    for k in ref.history:
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), f"state[{key!r}] diverged"


def _run_offload_pair(algo, state, batch, **kw):
    ref = run_rounds(algo, state, batch, ROUNDS, store="active", **kw)
    res = run_rounds(algo, state, batch, ROUNDS, store="offload", **kw)
    return res, ref


@pytest.mark.parametrize("use_scan", [True, False], ids=["scan", "legacy"])
@pytest.mark.parametrize("algo_key", FIVE)
def test_offload_matches_active_masked(problem, algo_key, use_scan):
    """5 algos x scan/legacy under uniform masked participation: the
    host-resident tiles replay the active store bit for bit (FedGiA's
    population tile shuttles the full buffers instead)."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    res, ref = _run_offload_pair(
        algo, state, batch, scan=use_scan,
        participation=make_policy("uniform", M, 0.5, seed=3))
    _assert_offload_equiv(res, ref)


@pytest.mark.parametrize("algo_key", FIVE)
def test_offload_matches_active_async(problem, algo_key):
    """Stale-x̄ rounds: the anchor buffer rides host memory and the
    engine applies the dense refresh write host-side (`anchor[refresh] =
    x̄` — the view's exact row select), ages stay device (m,) riders."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    res, ref = _run_offload_pair(algo, state, batch,
                                 participation=make_policy("periodic", M),
                                 async_rounds=True, max_staleness=2)
    _assert_offload_equiv(res, ref)


def test_offload_matches_active_async_zero_staleness(problem):
    """max_staleness=0 (always fresh): the host anchor is never read or
    written — still bitwise the active engine."""
    algo, state = _make(problem, "fedpd")
    _, batch = problem
    res, ref = _run_offload_pair(algo, state, batch,
                                 participation=make_policy("periodic", M),
                                 async_rounds=True, max_staleness=0)
    _assert_offload_equiv(res, ref)


@pytest.mark.parametrize("algo_key", ["fedavg", "scaffold"])
def test_offload_matches_active_clocked_weighted(problem, algo_key):
    """Wall-clock arrivals (tile capacity = m) + staleness-weighted
    eq. (11): the dense (m,) weights stay device-resident and gather by
    REAL row ids inside the tile round — bitwise the active store."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    clk = ComputeClock(M, 1.0 + (np.arange(M) % 3))
    res, ref = _run_offload_pair(algo, state, batch, clock=clk,
                                 max_staleness=3, stale_weighting="poly",
                                 stale_decay=0.5)
    _assert_offload_equiv(res, ref)


def test_offload_ef_stale_composition(problem):
    """EF residuals ride the host store: the codec's residual tile is
    gathered/advanced/scattered through the same host rows as any client
    state, composed with staleness and the byte-accurate wire clock —
    bitwise the active store, bytes_up included."""
    algo, state = _make(problem, "scaffold")
    _, batch = problem
    clk = ComputeClock(M, 1.0 + (np.arange(M) % 3), bandwidth_bps=1e6)
    res, ref = _run_offload_pair(algo, state, batch, clock=clk,
                                 max_staleness=2, compression="int8",
                                 error_feedback=True)
    _assert_offload_equiv(res, ref)


def test_offload_early_stop_matches_active(problem):
    """The offload loop's per-round host sync applies the eq.-(35) tol
    rule on the same metric stream — same stop round, same state."""
    algo, state = _make(problem, "fedgia", k0=5)
    _, batch = problem
    kw = dict(tol=1e-9, participation=make_policy("uniform", M, 0.5, seed=3))
    ref = run_rounds(algo, state, batch, 300, scan=False, store="active",
                     **kw)
    res = run_rounds(algo, state, batch, 300, store="offload", **kw)
    assert ref.stopped_early and res.stopped_early
    assert res.rounds_run == ref.rounds_run
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), key


def test_offload_reports_memory_extras(problem):
    """RoundResult.extras carries the offload footprint: the buffers that
    left the device and (where XLA reports it) the compiled tile round's
    peak device bytes."""
    algo, state = _make(problem, "fedpd")
    _, batch = problem
    res = run_rounds(algo, state, batch, 3, store="offload",
                     participation=make_policy("uniform", M, 0.5, seed=3))
    assert res.extras["host_resident_bytes"] > 0
    peak = res.extras["device_peak_bytes"]
    assert peak is None or peak > 0
    # dense/active paths don't populate extras
    ref = run_rounds(algo, state, batch, 3, store="active",
                     participation=make_policy("uniform", M, 0.5, seed=3))
    assert ref.extras == {}


def test_tile_state_accessors_are_identity():
    """tile_state=True: gather_state/scatter_state pass pre-gathered
    tiles through; plain gather/scatter keep REAL resident row
    semantics (the dense riders and the aggregation depend on it)."""
    mask = jnp.asarray([0, 1, 0, 1], bool)
    aset = pt.make_active_set(mask, capacity=2, tile_state=True)
    tile = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    assert aset.gather_state(tile) is tile
    assert aset.scatter_state(tile, tile * 2) is not tile
    np.testing.assert_array_equal(np.asarray(aset.scatter_state(tile,
                                                                tile * 2)),
                                  np.asarray(tile) * 2)
    dense = jnp.arange(4, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(aset.gather(dense)),
                                  [1.0, 3.0])
    # resident mode: gather_state == gather
    rset = pt.make_active_set(mask, capacity=2)
    buf = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    np.testing.assert_array_equal(np.asarray(rset.gather_state(buf)),
                                  np.asarray(rset.gather(buf)))


def test_offload_store_roundtrip_bitwise():
    """OffloadStore gather/scatter == the device store's
    gather_rows/scatter_rows, bit for bit (clip reads, drop writes)."""
    buf = jnp.arange(20, dtype=jnp.float32).reshape(5, 4)
    store = pt.OffloadStore({"z": buf})
    idx = pt.host_put(jnp.asarray([1, 3, 5], jnp.int32))  # 5 = sentinel
    tiles = store.gather_tiles(idx)
    np.testing.assert_array_equal(np.asarray(tiles["z"]),
                                  np.asarray(pt.gather_rows(buf, idx)))
    store.scatter_tiles(idx, {"z": tiles["z"] * -1.0})
    np.testing.assert_array_equal(
        np.asarray(store.buffers["z"]),
        np.asarray(pt.scatter_rows(buf, idx, tiles["z"] * -1.0)))
    assert store.nbytes == int(buf.nbytes)


def test_offload_validation(problem):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    pol = lambda: make_policy("uniform", M, 0.5, seed=0)
    with pytest.raises(ValueError, match="participant"):
        run_rounds(algo, state, batch, 2, store="offload")
    with pytest.raises(ValueError, match="flat"):
        run_rounds(algo, state, batch, 2, store="offload",
                   participation=pol(), flat=False)
    with pytest.raises(ValueError, match="no chunks"):
        run_rounds(algo, state, batch, 2, store="offload",
                   participation=pol(), chunk_size="auto")
    with pytest.raises(ValueError, match="overlap"):
        run_rounds(algo, state, batch, 2, store="offload",
                   participation=pol(), overlap="scatter")
    with pytest.raises(ValueError, match="unknown aggregate"):
        run_rounds(algo, state, batch, 2, store="active",
                   participation=pol(), aggregate="sparse")
    with pytest.raises(ValueError, match="packed"):
        run_rounds(algo, state, batch, 2, store="dense",
                   participation=pol(), aggregate="packed")


# ------------------------------------------ packed aggregation (fp tol)
@pytest.mark.parametrize("algo_key", ["fedavg", "scaffold"])
def test_packed_matches_dense_fp(problem, algo_key):
    """aggregate='packed' sums the (capacity, N) tile directly — fp
    tolerance vs the bitwise dense layout (~1 ulp: XLA associates the
    m-row and capacity-row reductions differently). SCAFFOLD also
    exercises the extra_mean rider (control-variate delta)."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    pol = lambda: make_policy("uniform", M, 0.5, seed=3)
    ref = run_rounds(algo, state, batch, ROUNDS, store="active",
                     participation=pol())
    res = run_rounds(algo, state, batch, ROUNDS, store="active",
                     aggregate="packed", participation=pol())
    assert res.rounds_run == ref.rounds_run
    for key in ref.state:
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7),
            res.state[key], ref.state[key])
    np.testing.assert_allclose(res.history["f_xbar"],
                               ref.history["f_xbar"], rtol=1e-5)


def test_packed_weighted_matches_dense_fp(problem):
    """The staleness-weighted packed sum gathers the dense (m,) weights
    by real row ids — fp-equal to the dense weighted aggregate."""
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    clk = lambda: ComputeClock(M, 1.0 + (np.arange(M) % 3))
    ref = run_rounds(algo, state, batch, ROUNDS, store="active", clock=clk(),
                     max_staleness=3, stale_weighting="poly", stale_decay=0.5)
    res = run_rounds(algo, state, batch, ROUNDS, store="active",
                     aggregate="packed", clock=clk(), max_staleness=3,
                     stale_weighting="poly", stale_decay=0.5)
    for key in ref.state:
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7),
            res.state[key], ref.state[key])


def test_packed_offload_matches_packed_active_bitwise(problem):
    """The two new modes compose: offload+packed is bitwise
    active+packed (the store moves data, the aggregate changes math —
    independent axes)."""
    algo, state = _make(problem, "scaffold")
    _, batch = problem
    pol = lambda: make_policy("uniform", M, 0.5, seed=3)
    ref = run_rounds(algo, state, batch, ROUNDS, store="active",
                     aggregate="packed", participation=pol())
    res = run_rounds(algo, state, batch, ROUNDS, store="offload",
                     aggregate="packed", participation=pol())
    _assert_offload_equiv(res, ref)


_SHARDED_PACKED_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from hlo_guard import assert_barrier_round
    from repro.config import FedConfig
    from repro.core import engine, make_algorithm, make_policy
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares
    from repro.utils import pytree as pt

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)

    fed = FedConfig(algorithm="scaffold", num_clients=m, k0=3, lr=0.01)
    algo = make_algorithm(fed, model.loss, model=model)
    s0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                   init_batch=batch)
    spec = pt.ravel_spec(s0["x"])
    s0f = engine.flatten_state(algo, s0, spec)
    cap = make_policy("uniform", m, 0.5).active_capacity
    st, b = engine.shard_inputs(algo, s0f, batch, mesh)
    mask = jnp.ones((m,), bool)

    def hlo(aggregate):
        rf = engine.make_round_fn(algo, mesh, masked=True, flat_spec=spec,
                                  active_capacity=cap, aggregate=aggregate)
        return jax.jit(rf).lower(st, b, mask).compile().as_text()

    txt = hlo("packed")
    assert_barrier_round(txt, "scaffold-packed")
    # under a mesh the sharded branch is ALREADY packed inside its one
    # psum: the flag must leave the lowered program unchanged
    assert txt == hlo("dense"), "packed flag changed the sharded program"
    print("PACKED_SHARDED_OK one model-size all-reduce")
    """
)


def test_packed_sharded_one_all_reduce():
    """The sharded packed round keeps eq. (11) as exactly ONE model-size
    all-reduce, and the packed flag is a program-level no-op under a
    mesh (the sharded branch already sums the packed tile)."""
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_PACKED_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=900,
    )
    assert "PACKED_SHARDED_OK" in out.stdout, out.stdout + out.stderr
