# NOTE: no XLA_FLAGS here — tests run on the single real CPU device.
# Multi-device integration tests spawn subprocesses that use
# `fake_device_env` below to set --xla_force_host_platform_device_count
# BEFORE importing jax (the flag must be set pre-import, and mutating it
# in-process would leak 8 fake devices into every other test).
import os
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

try:
    # Hypothesis profiles for the property suite (tests/test_hypothesis.py).
    # deadline=None: jit compilation makes first-example timing meaningless.
    # CI runs derandomized (db-less, reproducible across the matrix) via
    # HYPOTHESIS_PROFILE=ci; the default profile keeps local shrinking.
    from hypothesis import settings

    settings.register_profile("default", deadline=None, max_examples=50)
    settings.register_profile(
        "ci", deadline=None, max_examples=50, derandomize=True,
        database=None, print_blob=True,
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:  # hypothesis is optional: its tests importorskip
    pass


def fake_device_env(num_devices: int = 8) -> dict:
    """Environment for a subprocess that should see `num_devices` fake CPU
    devices: XLA_FLAGS set before jax import, PYTHONPATH pointing at src
    AND at this directory (the scripts import the shared `hlo_guard`
    collective classifier)."""
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep + tests_dir)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    return env


@pytest.fixture()
def rng():
    """Per-test numpy generator, freshly seeded EVERY test. A session-scoped
    mutable generator (the previous shape of this fixture) hands each
    consumer whatever draws the tests before it left behind — values then
    depend on execution order, which breaks under pytest-randomly's
    shuffling. Function scope makes every test's draws order-independent."""
    return np.random.default_rng(0)
