# NOTE: no XLA_FLAGS here — tests run on the single real CPU device.
# Multi-device integration tests spawn subprocesses that use
# `fake_device_env` below to set --xla_force_host_platform_device_count
# BEFORE importing jax (the flag must be set pre-import, and mutating it
# in-process would leak 8 fake devices into every other test).
import os
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)


def fake_device_env(num_devices: int = 8) -> dict:
    """Environment for a subprocess that should see `num_devices` fake CPU
    devices: XLA_FLAGS set before jax import, PYTHONPATH pointing at src."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    return env


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
