# NOTE: no XLA_FLAGS here — tests run on the single real CPU device.
# Multi-device integration tests spawn subprocesses that set
# --xla_force_host_platform_device_count BEFORE importing jax.
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
