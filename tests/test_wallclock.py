"""Wall-clock event-driven rounds + staleness-weighted aggregation.

Acceptance contract of the wall-clock subsystem (core/clock.py + the
`clock=` / `stale_weighting=` engine knobs):
  * uniform weighting: `stale_weighting="uniform"` is BITWISE identical
    to the PR-3 async engine (it passes weights=None into `client_mean`,
    so the lowered round is the same program) — all five algorithms,
    scan and legacy paths.
  * equal client speeds: a constant clock with identical speeds arrives
    everyone every round — BITWISE identical to the async engine under a
    full-participation arrival policy (all five algorithms, scan+legacy).
  * integer speeds generalise the periodic trace policy: constant speeds
    with a unit-speed client present produce the same arrival masks as
    `AvailabilityParticipation.from_periods`, hence identical runs.
  * event-driven time: `sim_time` matches the hand-computed event
    sequence and is nondecreasing; staleness stays bounded.
  * weighted aggregation: poly/exp schedules match a numpy reference;
    weighted scan == weighted legacy; the sharded weighted round still
    issues exactly one model-size all-reduce (HLO-asserted, subprocess).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fake_device_env
from repro.config import FedConfig
from repro.core import ComputeClock, LognormalClock, TraceClock, api, \
    make_algorithm, run_rounds
from repro.core.selection import AvailabilityParticipation, ParticipationPolicy

M, N, D, ROUNDS, CHUNK = 8, 20, 400, 12, 5

ALGO_SETUPS = {
    "fedgia": dict(algorithm="fedgia", sigma_t=0.2, h_policy="scalar", alpha=1.0),
    "fedgia_diag": dict(algorithm="fedgia", sigma_t=0.2, h_policy="diag_ema",
                        alpha=1.0),
    "fedavg": dict(algorithm="fedavg", lr=0.01),
    "fedprox": dict(algorithm="fedprox", lr=0.002, prox_mu=1e-4, inner_steps=3),
    "fedpd": dict(algorithm="fedpd", lr=0.05, fedpd_eta=1.0, inner_steps=3),
    "scaffold": dict(algorithm="scaffold", lr=0.01),
}


@pytest.fixture(scope="module")
def problem():
    from repro.data import linreg_noniid
    from repro.models import LeastSquares

    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def _make(problem, key):
    model, batch = problem
    fed = FedConfig(num_clients=M, k0=3, **ALGO_SETUPS[key])
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                      init_batch=batch)
    return algo, state, batch


def _state_leaves(state):
    for k, v in state.items():
        for leaf in jax.tree.leaves(v):
            yield k, np.asarray(leaf)


def _assert_bitwise(res, ref, label):
    assert res.rounds_run == ref.rounds_run
    for k in ref.history:  # the clock run adds sim_time on top
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=f"{label}/{k}")
    for (k, a), (_, b) in zip(_state_leaves(ref.state), _state_leaves(res.state)):
        np.testing.assert_array_equal(a, b, err_msg=f"{label}/state[{k}]")


# ------------------------------------------------------- bitwise identities
@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
@pytest.mark.parametrize("scan", [True, False], ids=["scan", "legacy"])
def test_uniform_weighting_bitwise_identical(problem, algo_key, scan):
    """stale_weighting="uniform" == the PR-3 async engine, bit for bit:
    uniform weighting resolves to weights=None, the same lowered round."""
    algo, state, batch = _make(problem, algo_key)
    pol = AvailabilityParticipation.from_periods(M, 1 + (np.arange(M) % 3),
                                                 horizon=ROUNDS)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK,
                     participation=pol, async_rounds=True, max_staleness=2)
    res = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK,
                     participation=pol, async_rounds=True, max_staleness=2,
                     stale_weighting="uniform", stale_decay=3.0)
    _assert_bitwise(res, ref, algo_key)


@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
@pytest.mark.parametrize("scan", [True, False], ids=["scan", "legacy"])
def test_equal_speed_clock_bitwise_identical_to_async(problem, algo_key, scan):
    """Identical client speeds => every client arrives every round => the
    clock run is bitwise the async engine under full-participation
    arrivals (the ISSUE-4 acceptance identity)."""
    algo, state, batch = _make(problem, algo_key)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK,
                     participation=ParticipationPolicy(M), async_rounds=True,
                     max_staleness=2)
    res = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK,
                     clock=ComputeClock(M, compute_s=2.5), max_staleness=2,
                     stale_weighting="uniform")
    _assert_bitwise(res, ref, algo_key)
    # event-driven time: rounds fire at each (equal) work-item finish
    np.testing.assert_allclose(res.history["sim_time"],
                               2.5 * np.arange(ROUNDS), rtol=1e-6)


@pytest.mark.parametrize("algo_key", ["fedgia", "scaffold"])
def test_integer_speed_clock_matches_periodic_policy(problem, algo_key):
    """Constant integer speeds (unit-speed client present) derive the SAME
    arrival masks as the from_periods trace => identical runs. The clock
    strictly generalises the PR-3 periodic arrival process."""
    algo, state, batch = _make(problem, algo_key)
    periods = np.array([1, 2, 4, 1, 2, 4, 1, 2])
    ref = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     participation=AvailabilityParticipation.from_periods(
                         M, periods, horizon=ROUNDS),
                     async_rounds=True, max_staleness=8)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     clock=ComputeClock(M, compute_s=periods.astype(float)),
                     max_staleness=8)
    _assert_bitwise(res, ref, algo_key)


def test_trace_clock_constant_rows_match_constant_clock(problem):
    """A trace whose rows all equal the constant speeds is the constant
    clock (trace-driven durations, same event sequence)."""
    algo, state, batch = _make(problem, "fedavg")
    speeds = 1.0 + (np.arange(M) % 4)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     clock=ComputeClock(M, compute_s=speeds), max_staleness=4)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     clock=TraceClock(M, np.tile(speeds, (5, 1))),
                     max_staleness=4)
    _assert_bitwise(res, ref, "trace")
    np.testing.assert_array_equal(res.history["sim_time"],
                                  ref.history["sim_time"])


# ------------------------------------------------------- event-driven time
def test_sim_time_and_staleness_are_event_driven(problem):
    """Hand-computed event sequence for speeds alternating 1 and 3: the
    server wakes at every fast-client finish (t = 0, 1, 2, ...), slow
    clients arrive every 3rd round, and their staleness cycles 1, 2, 3."""
    algo, state, batch = _make(problem, "fedavg")
    speeds = np.where(np.arange(M) % 2 == 0, 1.0, 3.0)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     clock=ComputeClock(M, compute_s=speeds), max_staleness=8)
    np.testing.assert_allclose(res.history["sim_time"], np.arange(ROUNDS),
                               rtol=1e-6)
    st = res.history["staleness"]  # (ROUNDS, M)
    t = np.arange(ROUNDS)
    for i in range(M):
        p = int(speeds[i])
        expect = np.where(t == 0, 0, ((t - 1) % p) + 1)
        np.testing.assert_array_equal(st[:, i], expect,
                                      err_msg=f"client {i} (speed {p})")


def test_lognormal_clock_scan_matches_legacy(problem):
    """The jitter key rides in the clock carry: the duration sequence is a
    pure function of the seed, so scan == legacy under lognormal times
    (and staleness stays bounded)."""
    algo, state, batch = _make(problem, "fedgia")
    clk = LognormalClock(M, compute_s=1.0 + (np.arange(M) % 3), sigma=0.6,
                         seed=4)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     clock=clk, max_staleness=3, stale_weighting="exp",
                     stale_decay=0.5)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=False, clock=clk,
                     max_staleness=3, stale_weighting="exp", stale_decay=0.5)
    assert set(res.history) == set(ref.history)
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    assert (res.history["staleness"] <= 3).all()
    sim = res.history["sim_time"]
    assert (np.diff(sim) >= 0).all() and sim[0] == 0.0


# --------------------------------------------------- weighted aggregation
def test_stale_weights_schedules():
    """poly/exp decay in anchor age; uniform resolves to None (the bitwise
    escape hatch `client_mean` keys on)."""
    ages = jnp.asarray([0, 1, 3, 7], jnp.int32)
    mk = lambda w, d: api.StaleXbar(anchor=(), age=ages, last_used=ages,
                                    max_staleness=8, weighting=w, decay=d)
    assert api.stale_weights(None) is None
    assert api.stale_weights(mk("uniform", 2.0)) is None
    np.testing.assert_allclose(api.stale_weights(mk("poly", 1.0)),
                               1.0 / (1.0 + np.array([0, 1, 3, 7])))
    np.testing.assert_allclose(api.stale_weights(mk("exp", 0.5)),
                               np.exp(-0.5 * np.array([0, 1, 3, 7])),
                               rtol=1e-6)


def test_client_mean_weights_numpy_reference(rng):
    """Weighted (and masked-weighted) client_mean == Σw·x / Σw in numpy."""
    x = jnp.asarray(rng.normal(size=(M, 5)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=M), jnp.float32)
    mask = jnp.asarray([True, False] * (M // 2))
    got = api.client_mean(x, weights=w)
    np.testing.assert_allclose(
        got, (np.asarray(w)[:, None] * np.asarray(x)).sum(0) / np.asarray(w).sum(),
        rtol=1e-6)
    got_m = api.client_mean(x, mask=mask, weights=w)
    wm = np.where(np.asarray(mask), np.asarray(w), 0.0)
    np.testing.assert_allclose(
        got_m, (wm[:, None] * np.asarray(x)).sum(0) / wm.sum(), rtol=1e-6)


@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
def test_weighted_scan_matches_legacy(problem, algo_key):
    """poly staleness weighting: identical weight/staleness threading on
    both engine paths, for every algorithm."""
    algo, state, batch = _make(problem, algo_key)
    pol = AvailabilityParticipation.from_periods(M, 1 + (np.arange(M) % 3),
                                                 horizon=ROUNDS)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     participation=pol, async_rounds=True, max_staleness=2,
                     stale_weighting="poly", stale_decay=1.0)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=False,
                     participation=pol, async_rounds=True, max_staleness=2,
                     stale_weighting="poly", stale_decay=1.0)
    assert res.rounds_run == ref.rounds_run == ROUNDS
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    for (k, a), (_, b) in zip(_state_leaves(ref.state), _state_leaves(res.state)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                   err_msg=f"state[{k}]")


def test_weighted_run_differs_from_uniform(problem):
    """A sanity direction check: non-uniform weighting actually changes
    the aggregation under a heterogeneous arrival process (the plumbing
    is not silently dropping the weights)."""
    algo, state, batch = _make(problem, "fedgia")
    clk = ComputeClock(M, compute_s=1.0 + (np.arange(M) % 4))
    uni = run_rounds(algo, state, batch, ROUNDS, clock=clk, max_staleness=4)
    wtd = run_rounds(algo, state, batch, ROUNDS, clock=clk, max_staleness=4,
                     stale_weighting="poly", stale_decay=2.0)
    assert not np.allclose(uni.history["f_xbar"], wtd.history["f_xbar"])


# ----------------------------------------------------------- engine guards
def test_clock_excludes_participation(problem):
    algo, state, batch = _make(problem, "fedgia")
    with pytest.raises(ValueError, match="mutually exclusive"):
        run_rounds(algo, state, batch, 2, clock=ComputeClock(M),
                   participation=ParticipationPolicy(M))


def test_clock_client_count_must_match(problem):
    algo, state, batch = _make(problem, "fedgia")
    with pytest.raises(ValueError, match="clients"):
        run_rounds(algo, state, batch, 2, clock=ComputeClock(M + 1))


def test_stale_weighting_requires_async(problem):
    algo, state, batch = _make(problem, "fedgia")
    with pytest.raises(ValueError, match="async"):
        run_rounds(algo, state, batch, 2, stale_weighting="poly")
    with pytest.raises(ValueError, match="stale_weighting"):
        run_rounds(algo, state, batch, 2, clock=ComputeClock(M),
                   stale_weighting="typo")


def test_stale_decay_must_be_positive(problem):
    """A negative decay would silently UPweight the stalest anchors."""
    algo, state, batch = _make(problem, "fedgia")
    with pytest.raises(ValueError, match="decay"):
        run_rounds(algo, state, batch, 2, clock=ComputeClock(M),
                   stale_weighting="poly", stale_decay=-1.0)
    # decay is ignored (and unvalidated) under uniform weighting
    run_rounds(algo, state, batch, 2, clock=ComputeClock(M),
               stale_weighting="uniform", stale_decay=-1.0)


# -------------------------------------------------- sharded one-psum check
_SHARDED_WEIGHTED_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from hlo_guard import model_size_all_reduces as count_ars
    from repro.config import FedConfig
    from repro.core import api, engine, make_algorithm, run_rounds
    from repro.core.clock import ComputeClock
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)
    fed = FedConfig(algorithm="fedgia", num_clients=m, k0=5, alpha=1.0,
                    sigma_t=0.3, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    s0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                   init_batch=batch)

    def model_size_all_reduces(weighting):
        rf = engine.make_round_fn(algo, mesh, masked=True, stale=True)
        st, b = engine.shard_inputs(algo, s0, batch, mesh)
        stale = api.init_stale_xbar(s0["x"], m, 2, weighting=weighting,
                                    decay=1.0)
        args = (st, b, jnp.ones((m,), bool), stale)
        return count_ars(jax.jit(rf).lower(*args).compile().as_text())

    uni, wtd = model_size_all_reduces("uniform"), model_size_all_reduces("poly")
    assert wtd == uni, (
        f"weighted aggregation changed the model-size all-reduce count: "
        f"{uni} -> {wtd}")

    # and the weighted sharded RUN matches the single-device run
    clk = ComputeClock(m, compute_s=1.0 + (np.arange(m) % 3))
    ref = run_rounds(algo, s0, batch, 10, scan=True, chunk_size=5, clock=clk,
                     max_staleness=2, stale_weighting="poly")
    res = run_rounds(algo, s0, batch, 10, scan=True, chunk_size=5, clock=clk,
                     max_staleness=2, stale_weighting="poly", mesh=mesh)
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-4, atol=1e-6, err_msg=k)
    print(f"WEIGHTED_SHARDED_OK model_size_all_reduces={wtd}")
    """
)


def test_weighted_sharded_one_psum_and_parity():
    """eq. (11) with weights= is still the round's ONE model-size
    all-reduce (the weight sum rides the same psum), and the weighted
    clock-driven sharded run matches single-device."""
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_WEIGHTED_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=600,
    )
    assert "WEIGHTED_SHARDED_OK" in out.stdout, out.stdout + out.stderr
