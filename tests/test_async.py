"""Staleness-aware async round engine: equivalence and invariants.

Acceptance contract of the async (stale-x̄) subsystem:
  * max_staleness=0: the async engine is BITWISE identical to the
    synchronous masked engine for all five algorithms, on both the scan
    and legacy paths — the staleness plumbing must cost nothing when the
    bound forces every client fresh.
  * bounded staleness: the per-round `staleness` history (the age of the
    anchor each client actually used) never exceeds max_staleness, for
    every client and round, and actually reaches the bound under a slow
    arrival process (the force-sync path is exercised).
  * arrival semantics: a client arriving after s silent rounds used
    x̄^(t-s) — checked against a hand-computed trace.
  * async scan == async legacy (same policy + staleness state threading).
  * sharded async == single-device async (subprocess, 8 fake devices),
    with the round still lowering to the same model-size all-reduce
    count as the synchronous round.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fake_device_env
from repro.config import FedConfig
from repro.core import UniformParticipation, make_algorithm, run_rounds
from repro.core.selection import AvailabilityParticipation

M, N, D, ROUNDS, CHUNK = 8, 20, 400, 12, 5

ALGO_SETUPS = {
    "fedgia": dict(algorithm="fedgia", sigma_t=0.2, h_policy="scalar", alpha=1.0),
    "fedgia_diag": dict(algorithm="fedgia", sigma_t=0.2, h_policy="diag_ema",
                        alpha=1.0),
    "fedavg": dict(algorithm="fedavg", lr=0.01),
    "fedprox": dict(algorithm="fedprox", lr=0.002, prox_mu=1e-4, inner_steps=3),
    "fedpd": dict(algorithm="fedpd", lr=0.05, fedpd_eta=1.0, inner_steps=3),
    "scaffold": dict(algorithm="scaffold", lr=0.01),
}


@pytest.fixture(scope="module")
def problem():
    from repro.data import linreg_noniid
    from repro.models import LeastSquares

    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def _make(problem, key):
    model, batch = problem
    fed = FedConfig(num_clients=M, k0=3, **ALGO_SETUPS[key])
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                      init_batch=batch)
    return algo, state, batch


def _state_leaves(state):
    for k, v in state.items():
        for leaf in jax.tree.leaves(v):
            yield k, np.asarray(leaf)


def _arrival_policy(horizon=ROUNDS, periods=None):
    if periods is None:
        periods = 1 + (np.arange(M) % 3)  # speeds 1, 2, 3 rounds
    return AvailabilityParticipation.from_periods(M, periods, horizon=horizon)


@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
@pytest.mark.parametrize("scan", [True, False], ids=["scan", "legacy"])
def test_zero_staleness_is_bitwise_identical(problem, algo_key, scan):
    """async max_staleness=0 == synchronous masked engine, bit for bit."""
    algo, state, batch = _make(problem, algo_key)
    pol = UniformParticipation(M, 0.5, seed=7)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK,
                     participation=pol)
    res = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK,
                     participation=pol, async_rounds=True, max_staleness=0)
    assert res.rounds_run == ref.rounds_run
    for k in ref.history:  # async adds staleness keys on top
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=f"{algo_key}/{k}")
    for (k, a), (_, b) in zip(_state_leaves(ref.state), _state_leaves(res.state)):
        np.testing.assert_array_equal(a, b, err_msg=f"{algo_key}/state[{k}]")
    np.testing.assert_array_equal(res.history["staleness"], 0)
    np.testing.assert_array_equal(res.history["staleness_max"], 0)


@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
@pytest.mark.parametrize("max_staleness", [1, 3])
def test_bounded_staleness_invariant(problem, algo_key, max_staleness):
    """s <= max_staleness for EVERY client and round; the bound is hit when
    the arrival process is slower than it (force-sync path exercised)."""
    algo, state, batch = _make(problem, algo_key)
    # client 0 arrives every round (otherwise empty arrival rows trigger
    # the dead-round full-sync fallback); the rest are slower than any
    # bound tested here, so only the forced server sync caps their age
    periods = np.full(M, 6)
    periods[0] = 1
    pol = _arrival_policy(periods=periods)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     participation=pol, async_rounds=True,
                     max_staleness=max_staleness)
    st = res.history["staleness"]
    assert st.shape == (ROUNDS, M)
    assert (st <= max_staleness).all(), f"{algo_key}: staleness bound broken"
    assert st.max() == max_staleness, "bound never reached: force-sync untested"


def test_arrival_staleness_sequence(problem):
    """Deterministic periodic arrivals give the hand-computable staleness
    pattern. Round 0 force-syncs everyone (s=0: nobody has downloaded
    anything yet). From then on a client computes against its PREVIOUS
    download — the overlap: its compute runs while the server aggregates —
    so a period-p client cycles s = ((t-1) mod p) + 1: even an every-round
    arriver carries the one-round pipeline delay, and an arrival after p
    rounds of silence used x̄^(t-p)."""
    algo, state, batch = _make(problem, "fedavg")
    periods = np.array([1, 2, 4, 1, 2, 4, 1, 2])
    pol = _arrival_policy(periods=periods, horizon=ROUNDS)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     participation=pol, async_rounds=True, max_staleness=8)
    st = res.history["staleness"]  # (ROUNDS, M)
    t = np.arange(ROUNDS)
    for i, p in enumerate(periods):
        expect = np.where(t == 0, 0, ((t - 1) % p) + 1)
        np.testing.assert_array_equal(
            st[:, i], expect,
            err_msg=f"client {i} (period {p}) staleness sequence")


@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
def test_async_scan_matches_legacy_loop(problem, algo_key):
    """Nonzero staleness: identical StaleXbar threading on both paths."""
    algo, state, batch = _make(problem, algo_key)
    pol = _arrival_policy()
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     participation=pol, async_rounds=True, max_staleness=2)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=False,
                     participation=pol, async_rounds=True, max_staleness=2)
    assert res.rounds_run == ref.rounds_run == ROUNDS
    assert set(res.history) == set(ref.history)
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    for (k, a), (_, b) in zip(_state_leaves(ref.state), _state_leaves(res.state)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                   err_msg=f"state[{k}]")


def test_async_requires_arrival_process(problem):
    algo, state, batch = _make(problem, "fedgia")
    with pytest.raises(ValueError, match="participation"):
        run_rounds(algo, state, batch, 2, async_rounds=True, max_staleness=1)


def test_async_early_stop_agrees(problem):
    """eq. 35 stopping composes with the staleness carry on both paths."""
    algo, state, batch = _make(problem, "fedgia")
    pol = _arrival_policy(horizon=300)
    ref = run_rounds(algo, state, batch, 300, tol=1e-7, scan=False,
                     participation=pol, async_rounds=True, max_staleness=2)
    res = run_rounds(algo, state, batch, 300, tol=1e-7, scan=True,
                     chunk_size=13, participation=pol, async_rounds=True,
                     max_staleness=2)
    assert ref.stopped_early and res.stopped_early
    assert res.rounds_run == ref.rounds_run
    assert len(res.history["staleness"]) == res.rounds_run


_SHARDED_ASYNC_SCRIPT = textwrap.dedent(
    """
    import re
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import FedConfig
    from repro.core import api, engine, make_algorithm, run_rounds
    from repro.core.selection import AvailabilityParticipation
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    for algo_name, kw, mesh in (
        ("fedgia", dict(sigma_t=0.3, h_policy="diag_ema", alpha=1.0),
         make_host_mesh(data=8)),
        ("scaffold", dict(lr=0.01), make_host_mesh(model=2, data=4)),
    ):
        fed = FedConfig(algorithm=algo_name, num_clients=m, k0=5, **kw)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        pol = AvailabilityParticipation.from_periods(
            m, 1 + (np.arange(m) % 3), horizon=10)
        ref = run_rounds(algo, s0, batch, 10, scan=True, chunk_size=5,
                         participation=pol, async_rounds=True,
                         max_staleness=2)
        res = run_rounds(algo, s0, batch, 10, scan=True, chunk_size=5,
                         participation=pol, async_rounds=True,
                         max_staleness=2, mesh=mesh)
        # rtol 1e-4: per-shard psum partial sums reduce in a different
        # order than the single-device sum (same as the masked engine)
        for k in ref.history:
            np.testing.assert_allclose(res.history[k], ref.history[k],
                                       rtol=1e-4, atol=1e-6,
                                       err_msg=f"{algo_name}/{k}")
        for key in ref.state:
            for a, b in zip(jax.tree.leaves(ref.state[key]),
                            jax.tree.leaves(res.state[key])):
                np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                           rtol=1e-4, atol=1e-6,
                                           err_msg=f"{algo_name}/{key}")
        assert res.history["staleness"].max() == 2
    print("ASYNC_SHARDED_OK")
    """
)


def test_async_sharded_matches_single_device():
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_ASYNC_SCRIPT], env=fake_device_env(8),
        capture_output=True, text=True, timeout=600,
    )
    assert "ASYNC_SHARDED_OK" in out.stdout, out.stdout + out.stderr
