"""REQUIRED per-arch smoke tests: a REDUCED variant of each assigned
architecture (2 layers, d_model<=512, <=4 experts) runs one forward/train
step on CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, list_architectures
from repro.models import Transformer

B, S = 2, 16


def make_batch(cfg, rng):
    if cfg.input_mode == "tokens":
        return {"tokens": jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeds":
        return {
            "embeds": jax.random.normal(rng, (B, S, cfg.d_model)),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        }
    return {
        "embeds": jax.random.normal(rng, (B, cfg.embed_prefix_len, cfg.d_model)),
        "tokens": jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", list_architectures())
def test_smoke_train_step(arch):
    cfg = ARCHITECTURES[arch].reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.num_experts <= 4
    model = Transformer(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)

    # one train step: loss + grad + SGD update
    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch
    )
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, f"{arch}: bad grads"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2, _ = model.loss(new_params, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", list_architectures())
def test_smoke_forward_shapes(arch):
    cfg = ARCHITECTURES[arch].reduced()
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    tokens = batch.get("tokens")
    logits, _, _ = model.forward(
        params,
        tokens=tokens[:, :-1] if tokens is not None else None,
        embeds=batch.get("embeds"),
    )
    exp_s = 0
    if "embeds" in batch:
        exp_s += batch["embeds"].shape[1]
    if tokens is not None:
        exp_s += tokens.shape[1] - 1
    assert logits.shape == (B, exp_s, cfg.vocab_size), f"{arch}: {logits.shape}"
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "rwkv6-3b", "hymba-1.5b",
                                  "deepseek-v3-671b"])
def test_smoke_decode(arch):
    """Prefill + one decode step: shape + finiteness across cache families."""
    cfg = ARCHITECTURES[arch].reduced()
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.ones((B, 8), jnp.int32)
    logits, cache = model.prefill(params, tokens=prompts, cache_len=32)
    assert logits.shape == (B, cfg.vocab_size)
    lg, cache = model.decode_step(
        params, cache, jnp.ones((B, 1), jnp.int32), jnp.asarray(8, jnp.int32)
    )
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())
