"""Serving-path correctness: prefill+decode must reproduce the train-mode
forward (teacher forcing), incl. the sliding-window ring-buffer cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import Transformer
from repro.models.attention import AttnMode

B = 2


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b", "qwen1.5-0.5b", "rwkv6-3b", "hymba-1.5b",
    # dropping MoE routes capacity per position group (the decode-step
    # group), so the drop pattern is causal and parity holds (moe.py).
    "deepseek-v3-671b",
])
def test_decode_matches_forward(arch):
    """logits from [prefill(t<8) + decode steps 8..11] == full forward."""
    cfg = ARCHITECTURES[arch].reduced()
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)

    full_logits, _, _ = model.forward(params, tokens=toks)

    last, cache = model.prefill(params, tokens=toks[:, :8], cache_len=T)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(full_logits[:, 7], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    # bf16 params: the decode path re-associates reductions (MLA absorbed
    # form, cache slot order), so logits differ by a few bf16 ulps.
    for t in range(8, T):
        last, cache = model.decode_step(
            params, cache, toks[:, t : t + 1], jnp.asarray(t, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(last, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=4e-2, atol=8e-2,
            err_msg=f"{arch}: decode step {t} diverges from forward",
        )


def test_sliding_window_ring_buffer():
    """Ring-buffer decode (cache_len=W < T) == full-cache decode with the
    same window mask — the long_500k mechanism."""
    cfg = ARCHITECTURES["tinyllama-1.1b"].reduced()
    W = cfg.sliding_window  # 64 in reduced config
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = W + 24  # force wrap-around
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)

    # reference: full cache, window-masked attention
    _, cache_full = model.prefill(params, tokens=toks[:, :W], cache_len=T, window=W)
    # ring buffer: cache of exactly W slots
    _, cache_ring = model.prefill(params, tokens=toks[:, :W], cache_len=W, window=W)

    for t in range(W, T):
        tok = toks[:, t : t + 1]
        pos = jnp.asarray(t, jnp.int32)
        lf, cache_full = model.decode_step(params, cache_full, tok, pos, window=W)
        lr, cache_ring = model.decode_step(params, cache_ring, tok, pos, window=W)
        # ring slot order permutes the bf16 reduction order: few-ulp noise
        np.testing.assert_allclose(
            np.asarray(lr, np.float32), np.asarray(lf, np.float32),
            rtol=4e-2, atol=8e-2, err_msg=f"ring buffer diverges at t={t}",
        )


def test_prefill_wrap_ring_buffer():
    """Prefilling more tokens than the ring size keeps only the last W —
    equivalent to prefilling the suffix (for window-limited attention)."""
    cfg = ARCHITECTURES["tinyllama-1.1b"].reduced()
    W = cfg.sliding_window
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = W + 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab_size)
    last_wrap, cache = model.prefill(params, tokens=toks, cache_len=W, window=W)
    full, _, _ = model.forward(
        params, tokens=toks, mode=AttnMode("train", window=W)
    )
    np.testing.assert_allclose(
        np.asarray(last_wrap, np.float32), np.asarray(full[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_fp8_cache_decode_close_to_bf16():
    """Quantized (fp8_e4m3) KV cache: decode logits stay close to the
    bf16-cache reference (§Perf H4)."""
    cfg = ARCHITECTURES["tinyllama-1.1b"].reduced()
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab_size)
    _, c16 = model.prefill(params, tokens=toks[:, :8], cache_len=T)
    _, c8 = model.prefill(params, tokens=toks[:, :8], cache_len=T,
                          cache_dtype=jnp.float8_e4m3fn)
    for t in range(8, T):
        tok, pos = toks[:, t : t + 1], jnp.asarray(t, jnp.int32)
        l16, c16 = model.decode_step(params, c16, tok, pos)
        l8, c8 = model.decode_step(params, c8, tok, pos)
        err = jnp.abs(l8.astype(jnp.float32) - l16.astype(jnp.float32)).max()
        scale = jnp.abs(l16.astype(jnp.float32)).max()
        assert float(err) < 0.15 * float(scale) + 0.5, f"t={t}: fp8 err {err}"
