"""Overlapped eq. (11) collectives (`run_rounds(overlap="scatter")`).

The overlap pipeline splits the round's one model-size all-reduce into an
EARLY reduce-scatter of this round's contribution plus a DEFERRED
all-gather of the consensus shard at the top of the NEXT round, carried in
the double-buffered `ovl_shard` state slot — so the model-size wire hides
behind the next round's local compute. The pipeline is value-preserving:
the consensus a round consumes is bit-for-bit the mean a barrier round
would have formed (the slot stores normalized means, seeded with x0).

Covers:
  * overlap="off" is THE SAME program as the PR-5 one-psum round:
    lowered-HLO string equality for all five algorithms (sharded,
    subprocess) and bitwise state/history equality (single device).
  * overlap="scatter" tracks the barrier run within fp tolerance for all
    five algorithms × sync/masked/async, scan and legacy (two different
    XLA programs fuse differently — ulp-level drift is expected, exact
    equality is not).
  * slot semantics pinned against an independent per-client reference on
    a 2-client example: the round consumes LAST round's consensus as its
    anchor, returns x == that consensus (one-round lag), and emits the
    slot holding THIS round's normalized contribution mean; f_xbar is the
    loss AT the consumed consensus.
  * collective budget (subprocess, 8 fake devices): the overlapped
    sharded round lowers to ZERO model-size all-reduces + exactly one
    reduce-scatter + one all-gather for five algorithms × sync/async ×
    dense/active × uncompressed/int8 (hlo_guard.assert_overlap_round).
  * pod-spanning client axis: make_host_mesh(pod=2, data=4) with
    client_axis=("pod", "data") is BITWISE the flat data=8 mesh, with
    and without overlap, and keeps the overlap collective budget.
  * hypothesis property: random algorithm / scan chunk size / straggler
    mask pattern — overlap="scatter" still tracks the barrier run.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fake_device_env
from repro.config import FedConfig
from repro.core import make_algorithm, make_policy, run_rounds
from repro.core.baselines.common import lr_schedule
from repro.core.engine import flatten_state
from repro.data import linreg_noniid
from repro.models import LeastSquares
from repro.utils import pytree as pt

M, N, D = 8, 20, 400
ROUNDS = 10

ALGO_SETUPS = {
    "fedgia_diag": dict(sigma_t=0.2, h_policy="diag_ema", alpha=0.5),
    "fedavg": dict(lr=0.01),
    "fedprox": dict(lr=0.002, prox_mu=1e-4, inner_steps=3),
    "fedpd": dict(lr=0.05, fedpd_eta=1.0, inner_steps=3),
    "scaffold": dict(lr=0.01),
}
FIVE = list(ALGO_SETUPS)

# value parity between two independently compiled programs: ulp-level
# drift from different fusion/FMA contraction is expected and fine
TOL = dict(rtol=1e-4, atol=1e-6)


@pytest.fixture(scope="module")
def problem():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def _make(problem, key, **overrides):
    model, batch = problem
    name = "fedgia" if key.startswith("fedgia") else key
    kwargs = dict(algorithm=name, num_clients=M, k0=3)
    kwargs.update(ALGO_SETUPS[key])
    kwargs.update(overrides)
    fed = FedConfig(**kwargs)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    return algo, state


def _mode_kwargs(mode):
    if mode == "sync":
        return {}
    pol = make_policy("straggler", M, 0.5, seed=0, drop_prob=0.3,
                      horizon=ROUNDS)
    if mode == "masked":
        return dict(participation=pol)
    return dict(participation=pol, async_rounds=True, max_staleness=2)


def _assert_bitwise(res, ref):
    assert res.rounds_run == ref.rounds_run
    assert set(res.history) == set(ref.history)
    for k in ref.history:
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), f"state[{key!r}] diverged"


# --------------------------------------------- overlap="off" is the old path
@pytest.mark.parametrize("key", FIVE)
def test_overlap_off_bitwise_identical(problem, key):
    """overlap="off" must not perturb the PR-5 program AT ALL: bitwise
    history and state against a run that never mentions overlap."""
    model, batch = problem
    algo, state = _make(problem, key)
    ref = run_rounds(algo, state, batch, ROUNDS)
    res = run_rounds(algo, state, batch, ROUNDS, overlap="off")
    _assert_bitwise(res, ref)


def test_overlap_validation(problem):
    model, batch = problem
    algo, state = _make(problem, "fedgia_diag")
    with pytest.raises(ValueError, match="overlap"):
        run_rounds(algo, state, batch, 2, overlap="bogus")
    with pytest.raises(ValueError, match="overlap"):
        run_rounds(algo, state, batch, 2, overlap="scatter", flat=False)


# ------------------------------------------ scatter == barrier (value parity)
@pytest.mark.parametrize("mode", ["sync", "masked", "async"])
@pytest.mark.parametrize("key", FIVE)
def test_overlap_scatter_matches_barrier(problem, key, mode):
    """The overlap pipeline is value-preserving: every round consumes
    exactly the consensus the barrier round would have formed, so the
    full history tracks the barrier run (fp tolerance — two different
    compiled programs). The carry slot never leaks into the final
    state."""
    model, batch = problem
    algo, state = _make(problem, key)
    kw = _mode_kwargs(mode)
    ref = run_rounds(algo, state, batch, ROUNDS, **kw)
    res = run_rounds(algo, state, batch, ROUNDS, overlap="scatter", **kw)
    assert res.rounds_run == ref.rounds_run
    assert "ovl_shard" not in res.state
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   err_msg=k, **TOL)
    for a, b in zip(jax.tree.leaves(res.state["x"]),
                    jax.tree.leaves(ref.state["x"])):
        np.testing.assert_allclose(a, b, **TOL)


@pytest.mark.parametrize("key", ["fedgia_diag", "scaffold"])
def test_overlap_scatter_legacy_loop(problem, key):
    """The legacy (scan=False) per-round dispatch threads the slot and
    finalizes it exactly like the scan path."""
    model, batch = problem
    algo, state = _make(problem, key)
    ref = run_rounds(algo, state, batch, 6, scan=False)
    res = run_rounds(algo, state, batch, 6, scan=False, overlap="scatter")
    assert "ovl_shard" not in res.state
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   err_msg=k, **TOL)


# -------------------------------------------------- slot semantics, 2 clients
def test_overlap_slot_semantics_two_clients():
    """Pin the carry-slot contract on a 2-client example against an
    independent per-client reference (plain python loop over jax.grad):

      * the round's anchor is the slot row passed IN (last round's
        consensus), not state["x"];
      * the returned x IS that consensus (one-round lag — the engine's
        finalize gathers the pending slot at run end);
      * the returned slot row is the normalized mean of THIS round's
        client trajectories;
      * f_xbar is the mean client loss AT the consumed consensus.
    """
    m, n, d = 2, 12, 64
    model = LeastSquares(n)
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(3, d, n, m).items()}
    fed = FedConfig(algorithm="fedavg", num_clients=m, k0=2, lr=0.05)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    spec = pt.ravel_spec(state["x"])
    sf = flatten_state(algo, state, spec)

    # an arbitrary consensus "in flight" from the previous round
    tail = (jnp.arange(spec.padded_size) < spec.size).astype(jnp.float32)
    consensus = jnp.asarray(
        np.random.default_rng(7).standard_normal(spec.padded_size),
        jnp.float32) * tail  # zero the lane-padding tail
    sf["ovl_shard"] = consensus[None]

    new_state, metrics = algo.round_flat(sf, batch, spec)

    # x == the consensus consumed this round, NOT a fresh mean
    np.testing.assert_array_equal(np.asarray(new_state["x"]),
                                  np.asarray(consensus))

    # independent per-client trajectories from the consensus anchor
    def client_loss(xv, i):
        cb = jax.tree.map(lambda v: v[i], batch)
        return model.loss(spec.unravel(xv), cb)[0]

    trajs, losses_at_anchor = [], []
    for i in range(m):
        xv = consensus
        losses_at_anchor.append(float(client_loss(xv, i)))
        for j in range(fed.k0):
            g = jax.grad(client_loss)(xv, i)
            xv = xv - lr_schedule(fed.lr, jnp.int32(j)) * g
        trajs.append(np.asarray(xv))
    np.testing.assert_allclose(np.asarray(new_state["ovl_shard"][0]),
                               np.mean(trajs, axis=0),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(metrics["f_xbar"]),
                               np.mean(losses_at_anchor),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------- sharded subprocess checks
_OVERLAP_OFF_PROGRAM_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp
    from hlo_guard import assert_barrier_round
    from repro.config import FedConfig
    from repro.core import engine, make_algorithm
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares
    from repro.utils import pytree as pt

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)

    for name in ("fedgia", "fedavg", "fedprox", "fedpd", "scaffold"):
        fed = FedConfig(algorithm=name, num_clients=m, k0=3, alpha=1.0,
                        sigma_t=0.3, h_policy="diag_ema", lr=0.01)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        spec = pt.ravel_spec(s0["x"])
        s0f = engine.flatten_state(algo, s0, spec)
        rf_base = engine.make_round_fn(algo, mesh, masked=True,
                                       flat_spec=spec)
        rf_off = engine.make_round_fn(algo, mesh, masked=True,
                                      flat_spec=spec, overlap="off")
        st, b = engine.shard_inputs(algo, s0f, batch, mesh)
        args = (st, b, jnp.ones((m,), bool))
        txt_base = jax.jit(rf_base).lower(*args).as_text()
        txt_off = jax.jit(rf_off).lower(*args).as_text()
        assert txt_base == txt_off, name + ": overlap='off' changed the program"
        assert_barrier_round(jax.jit(rf_off).lower(*args).compile().as_text(),
                             name)
    print("OVERLAP_OFF_SAME_PROGRAM_OK all five algorithms")
    """
)


def test_overlap_off_same_lowered_program():
    """overlap="off" must lower to CHARACTER-IDENTICAL StableHLO as the
    round fn built without the overlap argument (the PR-5 one-psum
    program), for all five algorithms on the sharded path."""
    out = subprocess.run(
        [sys.executable, "-c", _OVERLAP_OFF_PROGRAM_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=900,
    )
    assert "OVERLAP_OFF_SAME_PROGRAM_OK" in out.stdout, out.stdout + out.stderr


_OVERLAP_MATRIX_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp
    from hlo_guard import assert_overlap_round
    from repro.config import FedConfig
    from repro.core import api, compress, engine, make_algorithm, make_policy
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares
    from repro.utils import pytree as pt

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)
    cap = make_policy("uniform", m, 0.5).active_capacity

    def overlap_hlo(algo_name, stale, active, codec):
        fed = FedConfig(algorithm=algo_name, num_clients=m, k0=3, alpha=1.0,
                        sigma_t=0.3, h_policy="diag_ema", lr=0.01)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        spec = pt.ravel_spec(s0["x"])
        s0f = engine.flatten_state(algo, s0, spec)
        rows = int(getattr(algo, "overlap_slot_rows", 1))
        s0f["ovl_shard"] = jnp.zeros((rows, spec.padded_size),
                                     s0f["x"].dtype)
        kw = dict(masked=True, stale=stale, flat_spec=spec,
                  overlap="scatter")
        if active:
            kw["active_capacity"] = cap
        if codec:
            kw["compressor"] = compress.make_compressor(codec)
        rf = engine.make_round_fn(algo, mesh, **kw)
        st, b = engine.shard_inputs(algo, s0f, batch, mesh)
        args = (st, b, jnp.ones((m,), bool))
        if stale:
            args = args + (api.init_stale_xbar(s0f["x"], m, 2),)
        return jax.jit(rf).lower(*args).compile().as_text()

    checked = 0
    for name in ("fedgia", "fedavg", "fedprox", "fedpd", "scaffold"):
        for stale in (False, True):
            for active in (False, True):
                for codec in (None, "int8"):
                    label = (name + "/stale=" + str(stale) + "/active="
                             + str(active) + "/codec=" + str(codec))
                    assert_overlap_round(
                        overlap_hlo(name, stale, active, codec), label)
                    checked += 1
    print("OVERLAP_MATRIX_OK", checked, "variants, zero model-size all-reduce")
    """
)


def test_overlap_matrix_collective_budget():
    """The tentpole's wire contract, exhaustively: the overlapped sharded
    round lowers to ZERO model-size all-reduces and exactly ONE
    reduce-scatter + ONE all-gather — five algorithms × sync/async ×
    dense/active store × uncompressed/int8 uplink (40 lowered programs,
    all classified by the shared hlo_guard)."""
    out = subprocess.run(
        [sys.executable, "-c", _OVERLAP_MATRIX_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=900,
    )
    assert "OVERLAP_MATRIX_OK" in out.stdout, out.stdout + out.stderr


_POD_AXIS_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from hlo_guard import assert_overlap_round
    from repro.config import FedConfig
    from repro.core import api, engine, make_algorithm, run_rounds
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares
    from repro.utils import pytree as pt

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh8 = make_host_mesh(data=8)
    meshp = make_host_mesh(pod=2, data=4)

    fed = FedConfig(algorithm="fedgia", num_clients=m, k0=3, alpha=1.0,
                    sigma_t=0.3, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    s0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                   init_batch=batch)

    def bitwise(a, b):
        for k in a.history:
            np.testing.assert_array_equal(a.history[k], b.history[k],
                                          err_msg=k)
        for key in b.state:
            ok = jax.tree.map(lambda x, y: bool(jnp.array_equal(x, y)),
                              a.state[key], b.state[key])
            assert all(jax.tree.leaves(ok)), key

    # compound ("pod", "data") client axis == flat data axis, bitwise
    r8 = run_rounds(algo, s0, batch, 10, mesh=mesh8)
    rp = run_rounds(algo, s0, batch, 10, mesh=meshp,
                    client_axis=("pod", "data"))
    bitwise(rp, r8)

    # and with overlapped collectives on top
    o8 = run_rounds(algo, s0, batch, 10, mesh=mesh8, overlap="scatter")
    op = run_rounds(algo, s0, batch, 10, mesh=meshp,
                    client_axis=("pod", "data"), overlap="scatter")
    bitwise(op, o8)

    # the overlap collective budget holds over the compound axis
    spec = pt.ravel_spec(s0["x"])
    s0f = engine.flatten_state(algo, s0, spec)
    s0f["ovl_shard"] = jnp.zeros((1, spec.padded_size), s0f["x"].dtype)
    rf = engine.make_round_fn(algo, meshp, client_axis=("pod", "data"),
                              masked=True, flat_spec=spec, overlap="scatter")
    st, b = engine.shard_inputs(algo, s0f, batch, meshp,
                                client_axis=("pod", "data"))
    txt = jax.jit(rf).lower(st, b, jnp.ones((m,), bool)).compile().as_text()
    assert_overlap_round(txt, "pod-axis")
    print("POD_AXIS_OK bitwise over (pod, data), overlap budget holds")
    """
)


def test_pod_axis_bitwise_and_overlap_budget():
    """Lifting the client axis from 'data' to a compound ("pod", "data")
    mesh is a pure re-layout: runs are BITWISE the flat data=8 mesh, with
    and without overlap, and the overlapped round keeps its 1 RS + 1 AG
    + 0 model-size AR budget over the compound axis."""
    out = subprocess.run(
        [sys.executable, "-c", _POD_AXIS_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=900,
    )
    assert "POD_AXIS_OK" in out.stdout, out.stdout + out.stderr


# ------------------------------------------------------- hypothesis property
@pytest.mark.parametrize("key,chunk,seed,drop", [
    ("fedgia_diag", 1, 3, 0.6),
    ("scaffold", 3, 1, 0.3),
    ("fedpd", 5, 2, 0.0),
])
def test_overlap_tracks_barrier_fixed_draws(problem, key, chunk, seed, drop):
    """Deterministic slice of the property below (runs even where
    hypothesis is not installed): scatter == barrier across chunk sizes
    and straggler mask patterns."""
    model, batch = problem
    algo, state = _make(problem, key)
    pol = make_policy("straggler", M, 0.5, seed=seed, drop_prob=drop,
                      horizon=6)
    kw = dict(chunk_size=chunk, participation=pol)
    ref = run_rounds(algo, state, batch, 6, **kw)
    res = run_rounds(algo, state, batch, 6, overlap="scatter", **kw)
    assert "ovl_shard" not in res.state
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   err_msg=k, **TOL)


def test_overlap_property_random_algo_chunk_mask(problem):
    """Property test: overlap="scatter" tracks the barrier run for any
    (algorithm, scan chunk size, straggler mask pattern) draw."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    model, batch = problem
    rounds = 6

    @settings(max_examples=15, deadline=None)
    @given(key=st.sampled_from(FIVE),
           chunk=st.sampled_from([0, 1, 3, 5]),
           seed=st.integers(min_value=0, max_value=4),
           drop=st.sampled_from([0.0, 0.3, 0.6]))
    def inner(key, chunk, seed, drop):
        algo, state = _make(problem, key)
        pol = make_policy("straggler", M, 0.5, seed=seed, drop_prob=drop,
                          horizon=rounds)
        kw = dict(chunk_size=chunk, participation=pol)
        ref = run_rounds(algo, state, batch, rounds, **kw)
        res = run_rounds(algo, state, batch, rounds, overlap="scatter", **kw)
        assert "ovl_shard" not in res.state
        for k in ref.history:
            np.testing.assert_allclose(res.history[k], ref.history[k],
                                       err_msg=f"{key}/{chunk}/{seed}: {k}",
                                       **TOL)

    inner()
