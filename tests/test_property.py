"""Hypothesis property tests on the system's invariants.

`hypothesis` is an optional dev dependency (requirements-dev.txt); the
suite degrades gracefully to the non-property tests when it is absent —
the collapsed-vs-unrolled invariant keeps deterministic coverage in
tests/test_fedgia_math.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import FedConfig
from repro.core import make_algorithm
from repro.data import make_client_batches
from repro.kernels.fedgia_update import fedgia_update, fedgia_update_ref
from repro.models import LeastSquares
from repro.utils import pytree as pt

SETTINGS = dict(max_examples=15, deadline=None)


# ------------------------------------------------------ kernel == reference
@given(
    n=st.integers(8, 2000),
    k0=st.integers(1, 12),
    sel=st.booleans(),
    sigma=st.floats(0.05, 5.0),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_fused_update_equals_unrolled(n, k0, sel, sigma, seed):
    """DESIGN §6 B1: closed-form collapse is exact for ANY (n, k0, sigma, h)."""
    r = np.random.default_rng(seed)
    xbar = jnp.asarray(r.standard_normal(n), jnp.float32)
    g = jnp.asarray(r.standard_normal(n), jnp.float32)
    pi = jnp.asarray(r.standard_normal(n), jnp.float32)
    h = jnp.asarray(r.uniform(0.0, 4.0, n), jnp.float32)
    out = fedgia_update(xbar, g, pi, h, sel, jnp.float32(sigma), 8, k0=k0,
                        interpret=True)
    ref = fedgia_update_ref(xbar, g, pi, h, jnp.asarray(sel), jnp.float32(sigma),
                            8, k0=k0)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-4)


# --------------------------------------------------- algorithmic invariants
def _problem(seed, m=6, n=12, d=120):
    r = np.random.default_rng(seed)
    A = r.standard_normal((d, n)).astype(np.float32)
    x_star = r.standard_normal(n).astype(np.float32)
    b = (A @ x_star + 0.05 * r.standard_normal(d)).astype(np.float32)
    sizes = [d // m] * m
    batch = make_client_batches({"A": A, "b": b}, sizes)
    return LeastSquares(n), {k: jnp.asarray(v) for k, v in batch.items()}


@given(seed=st.integers(0, 2**16), k0=st.integers(1, 8),
       alpha=st.sampled_from([0.25, 0.5, 1.0]))
@settings(**SETTINGS)
def test_lagrangian_never_increases(seed, k0, alpha):
    """Lemma IV.1 holds for random problems, any k0 and selection fraction."""
    model, batch = _problem(seed)
    fed = FedConfig(algorithm="fedgia", num_clients=6, k0=k0, alpha=alpha,
                    sigma_t=6.0, h_policy="scalar")
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(seed), init_batch=batch)
    prev = float(algo.lagrangian(state, batch))
    for _ in range(6):
        state, _ = algo.round(state, batch)
        cur = float(algo.lagrangian(state, batch))
        assert cur <= prev + 1e-5 * max(1.0, abs(prev))
        prev = cur


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_aggregation_permutation_invariant(seed):
    """Server aggregate is a mean: permuting clients must not change x̄."""
    model, batch = _problem(seed)
    fed = FedConfig(algorithm="fedgia", num_clients=6, k0=3, alpha=1.0,
                    sigma_t=0.3)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(0), init_batch=batch)
    state, _ = algo.round(state, batch)

    perm = np.random.default_rng(seed).permutation(6)
    state_p = dict(state)
    state_p["z"] = jax.tree.map(lambda a: a[perm], state["z"])
    state_p["pi"] = jax.tree.map(lambda a: a[perm], state["pi"])
    batch_p = jax.tree.map(lambda a: a[perm], batch)
    s1, _ = algo.round(state, batch)
    s2, _ = algo.round(state_p, batch_p)
    np.testing.assert_allclose(
        np.asarray(s1["x"]["x"]), np.asarray(s2["x"]["x"]), rtol=1e-5, atol=1e-6
    )


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_consensus_at_fixed_point(seed):
    """Stationary point (eq. 9): x_i = x̄ for all i and sum(pi) ≈ 0."""
    model, batch = _problem(seed)
    fed = FedConfig(algorithm="fedgia", num_clients=6, k0=5, alpha=1.0,
                    sigma_t=0.3)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(0), init_batch=batch)
    rnd = jax.jit(algo.round)
    for _ in range(250):
        state, met = rnd(state, batch)
        if float(met["grad_sq_norm"]) < 1e-12:
            break
    xc = algo.client_params(state)
    xbar = np.asarray(state["x"]["x"])
    scale = max(1.0, float(np.abs(xbar).max()))
    # stopping is on the MEAN gradient; consensus converges at its own
    # (geometric) rate, so allow a loose-but-shrinking residual.
    spread = np.abs(np.asarray(xc["x"]) - xbar[None]).max()
    assert spread < 5e-2 * scale, f"no consensus: {spread}"
    pi_sum = np.abs(np.asarray(state["pi"]["x"]).sum(0)).max()
    assert pi_sum < 5e-2 * scale, f"duals do not cancel: {pi_sum}"


@given(seed=st.integers(0, 2**16), vocab=st.sampled_from([64, 257]))
@settings(max_examples=6, deadline=None)
def test_loss_finite_for_random_tokens(seed, vocab):
    """Model loss is finite for arbitrary token streams (no NaN traps)."""
    import dataclasses

    from repro.configs import ARCHITECTURES
    from repro.models import Transformer

    cfg = dataclasses.replace(ARCHITECTURES["tinyllama-1.1b"].reduced(),
                              vocab_size=vocab)
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 17), 0, vocab)
    loss, _ = model.loss(params, {"tokens": toks})
    assert bool(jnp.isfinite(loss))
