"""Property tests for the flat round-state containers (utils/pytree.py).

The flat engine rests on two data-layout contracts: `RavelSpec` (the
lane-padded ravel of the model pytree PR-5 built the comm buffer on) and
`ActiveSet` (the packed participant tile of the active client store).
This suite drives both with randomized shapes, dtypes and masks —
including the lane-boundary edges N % LANES in {0, 1, LANES-1} — where
the deterministic tests in test_flat.py / test_store.py pin single
examples.

`hypothesis` is an optional dev dependency (requirements-dev.txt); the
profiles (deadline=None, derandomized under HYPOTHESIS_PROFILE=ci) live
in conftest.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.faults import Screening, screen_rows
from repro.utils import pytree as pt

SETTINGS = dict(max_examples=25, deadline=None)

_DTYPES = [np.float32, np.float16, np.int32]


@st.composite
def leaf_specs(draw):
    """1-4 leaves, each a 0-3 dim shape of small axes, mixed dtypes.
    Values are small integers, exactly representable in every dtype the
    spec's promotion can pick — so ravel->unravel must be EXACT."""
    n_leaves = draw(st.integers(1, 4))
    out = []
    for i in range(n_leaves):
        shape = tuple(draw(st.lists(st.integers(1, 7), min_size=0,
                                    max_size=3)))
        dtype = draw(st.sampled_from(_DTYPES))
        out.append((f"leaf{i}", shape, dtype))
    return out


def _build_tree(specs, seed, stack=None):
    r = np.random.default_rng(seed)
    tree = {}
    for name, shape, dtype in specs:
        full = ((stack,) if stack else ()) + shape
        tree[name] = jnp.asarray(
            r.integers(-100, 100, size=full).astype(dtype))
    return tree


@given(specs=leaf_specs(), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_ravel_unravel_roundtrip(specs, seed):
    tree = _build_tree(specs, seed)
    spec = pt.ravel_spec(tree)
    assert spec.size == sum(int(np.prod(s)) for _, s, _ in specs)
    assert spec.padded_size % pt.LANES == 0
    assert spec.padded_size >= spec.size > spec.padded_size - pt.LANES
    flat = spec.ravel(tree)
    assert flat.shape == (spec.padded_size,)
    if spec.padded_size > spec.size:  # zero tail, exactly
        assert float(jnp.abs(flat[spec.size:]).max()) == 0.0
    back = spec.unravel(flat)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]), err_msg=k)


@given(specs=leaf_specs(), seed=st.integers(0, 2**16), m=st.integers(1, 5))
@settings(**SETTINGS)
def test_ravel_stacked_roundtrip(specs, seed, m):
    stacked = _build_tree(specs, seed, stack=m)
    spec = pt.ravel_spec({k: v[0] for k, v in stacked.items()})
    flat = spec.ravel_stacked(stacked)
    assert flat.shape == (m, spec.padded_size)
    back = spec.unravel_stacked(flat)
    for k in stacked:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(stacked[k]), err_msg=k)


@given(q=st.integers(1, 3), r=st.sampled_from([0, 1, pt.LANES - 1]),
       seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_ravel_lane_boundary_sizes(q, r, seed):
    """N % LANES in {0, 1, LANES-1}: exact multiple (no padding), one
    element past a boundary (maximal padding), one short of a boundary
    (single padding lane)."""
    n = q * pt.LANES + r
    tree = {"w": jnp.asarray(
        np.random.default_rng(seed).standard_normal(n), jnp.float32)}
    spec = pt.ravel_spec(tree)
    assert spec.size == n
    assert spec.padded_size == (n if r == 0 else (q + 1) * pt.LANES)
    flat = spec.ravel(tree)
    if r:
        assert float(jnp.abs(flat[n:]).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(spec.unravel(flat)["w"]),
                                  np.asarray(tree["w"]))


# ------------------------------------------------------------------ ActiveSet
@st.composite
def masks(draw):
    m = draw(st.integers(1, 16))
    bits = draw(st.lists(st.booleans(), min_size=m, max_size=m))
    pop = sum(bits)
    capacity = draw(st.integers(max(1, pop), m))
    return np.asarray(bits, bool), capacity


@given(mc=masks())
@settings(**SETTINGS)
def test_active_set_pack_invariants(mc):
    mask, capacity = mc
    m = mask.shape[0]
    aset = pt.make_active_set(jnp.asarray(mask), capacity)
    idx = np.asarray(aset.idx)
    # packed ids: the mask's True rows in ascending order, sentinel-padded
    np.testing.assert_array_equal(idx[: mask.sum()], np.nonzero(mask)[0])
    assert (idx[mask.sum():] == m).all()
    np.testing.assert_array_equal(np.asarray(aset.valid), idx < m)
    assert float(aset.count) == float(mask.sum())
    assert aset.capacity == capacity and aset.num_clients == m


@given(mc=masks(), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_active_gather_scatter_identity(mc, seed):
    """scatter(buf, gather(buf)) == buf bitwise: padding rows carry the
    sentinel index and are dropped, resident rows rewrite themselves."""
    mask, capacity = mc
    m = mask.shape[0]
    buf = jnp.asarray(
        np.random.default_rng(seed).standard_normal((m, 5)), jnp.float32)
    aset = pt.make_active_set(jnp.asarray(mask), capacity)
    out = aset.scatter(buf, aset.gather(buf))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(buf))


@given(mc=masks(), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_active_scatter_touches_exactly_masked_rows(mc, seed):
    """Writing a modified tile back changes the participant rows and
    NOTHING else — the dense masked_update freeze, row for row."""
    mask, capacity = mc
    m = mask.shape[0]
    buf = jnp.asarray(
        np.random.default_rng(seed).standard_normal((m, 4)), jnp.float32)
    aset = pt.make_active_set(jnp.asarray(mask), capacity)
    out = np.asarray(aset.scatter(buf, aset.gather(buf) + 1.0))
    expect = np.asarray(buf) + mask[:, None].astype(np.float32)
    np.testing.assert_array_equal(out, expect)


# ------------------------------------------------------------------ Screening
@st.composite
def screened_uploads(draw):
    """A (rows, n) contribution buffer seeded with random NaN/Inf cells
    and heavy-tailed magnitudes, an optional participation mask, and an
    optional clip norm — the full screen_rows input space."""
    rows = draw(st.integers(1, 12))
    n = draw(st.integers(1, 9))
    r = np.random.default_rng(draw(st.integers(0, 2**16)))
    buf = (r.standard_normal((rows, n)) *
           10.0 ** r.integers(-2, 4, size=(rows, 1))).astype(np.float32)
    for _ in range(draw(st.integers(0, rows))):  # poison some cells
        buf[r.integers(rows), r.integers(n)] = draw(
            st.sampled_from([np.nan, np.inf, -np.inf]))
    mask = (np.asarray(draw(st.lists(st.booleans(), min_size=rows,
                                     max_size=rows)), bool)
            if draw(st.booleans()) else None)
    clip = draw(st.one_of(st.none(),
                          st.floats(1e-3, 1e4, allow_nan=False)))
    return buf, mask, clip


@given(sc=screened_uploads())
@settings(**SETTINGS)
def test_screen_rows_contract(sc):
    """screen_rows' full contract, under random poisoning:
      * smask ⊆ the participation mask, and smask is exactly
        mask ∧ row-is-finite — screening never admits a non-arrival;
      * no non-finite value survives into the returned buffer (so none
        can reach eq. (11)'s psum), screened-out rows are exact zeros;
      * with clip_norm set, every surviving row lands on or inside the
        clip ball (small fp slack for the rescale), and rows already
        inside it pass through BITWISE."""
    buf, mask, clip = sc
    out, smask = screen_rows(
        jnp.asarray(buf), None if mask is None else jnp.asarray(mask),
        Screening(clip_norm=clip))
    out, smask = np.asarray(out), np.asarray(smask)
    finite_rows = np.isfinite(buf).all(axis=-1)
    expect_mask = finite_rows if mask is None else (mask & finite_rows)
    np.testing.assert_array_equal(smask, expect_mask)
    if mask is not None:
        assert not (smask & ~mask).any()
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[~smask],
                                  np.zeros_like(out[~smask]))
    if clip is not None:
        nrm = np.linalg.norm(out.astype(np.float64), axis=-1)
        assert (nrm <= clip * (1 + 1e-5)).all()
        inside = smask & (np.linalg.norm(
            np.where(expect_mask[:, None], buf, 0.0).astype(np.float64),
            axis=-1) <= clip)
        np.testing.assert_array_equal(out[inside], buf[inside])


@given(mc=masks(), seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_active_zero_invalid_matches_dense_masked_sum(mc, seed):
    """Reductions over the zeroed tile equal the dense masked reductions
    BITWISE (ascending pack + exact-zero padding rows — the active
    store's aggregation contract)."""
    mask, capacity = mc
    m = mask.shape[0]
    buf = jnp.asarray(
        np.random.default_rng(seed).standard_normal((m, 3)), jnp.float32)
    aset = pt.make_active_set(jnp.asarray(mask), capacity)
    tile = aset.zero_invalid(aset.gather(buf))
    dense = jnp.where(jnp.asarray(mask)[:, None], buf, 0.0)
    # pad the dense operand list to the tile's row count: summing zeros
    # in a different order could differ bitwise, so compare via sorted
    # nonzero rows instead — ascending pack preserves row order exactly
    np.testing.assert_array_equal(
        np.asarray(tile)[: mask.sum()], np.asarray(dense)[mask])
    np.testing.assert_array_equal(
        np.asarray(tile)[mask.sum():],
        np.zeros((capacity - mask.sum(), 3), np.float32))
