"""Compressed eq. (11) communication (core/compress.py + engine wiring).

Acceptance contract of the compression subsystem:
  * codec units: bf16 is exact on representable values, int8's decode
    error is bounded by its per-row grid (and unbiased under stochastic
    rounding), top-k keeps exactly the k largest-|·| lanes and preserves
    the lane-padded zero tail, and the wire-byte model is exact.
  * error feedback telescopes: Σ decoded uploads + final residual equals
    Σ raw uploads to fp tolerance; masked-out clients' residuals freeze.
  * `compression="none"` is BITWISE identical to the uncompressed engine
    — all five algorithms, scan and legacy, dense and active stores: the
    engine resolves the identity codec to "no compressor", so the
    lowered round is THE SAME program.
  * decompress-before-reduce: the compressed sharded round still lowers
    to exactly ONE model-size all-reduce (HLO-asserted, subprocess), and
    the sharded compressed run matches single-device — the stochastic
    per-client keys are derived from GLOBAL row ids.
  * byte-accurate clock: `bytes_up`/`bytes_down` and the wire term in
    `sim_time` match hand-computed goldens; `bandwidth_bps=None` keeps
    the PR-4/5 clock bitwise — asserted against a committed
    BENCH_wallclock.baseline.json row.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import fake_device_env
from repro.config import FedConfig
from repro.core import api, compress, make_algorithm, make_policy, run_rounds
from repro.core.clock import ComputeClock
from repro.core.compress import (
    HEADER_BYTES,
    Bf16Compressor,
    Int8Compressor,
    NoneCompressor,
    TopKCompressor,
    downlink_bytes,
    make_compressor,
    uplink_bytes,
)
from repro.data import linreg_noniid
from repro.models import LeastSquares
from repro.utils import pytree as pt

M, N, D = 8, 20, 400
ROUNDS = 12
CHUNK = 5

ALGO_SETUPS = {
    "fedgia_diag": dict(sigma_t=0.2, h_policy="diag_ema", alpha=0.5),
    "fedavg": dict(lr=0.01),
    "fedprox": dict(lr=0.002, prox_mu=1e-4, inner_steps=3),
    "fedpd": dict(lr=0.05, fedpd_eta=1.0, inner_steps=3),
    "scaffold": dict(lr=0.01),
}
FIVE = sorted(ALGO_SETUPS)


@pytest.fixture(scope="module")
def problem():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def _make(problem, key, **overrides):
    model, batch = problem
    name = "fedgia" if key.startswith("fedgia") else key
    kwargs = dict(algorithm=name, num_clients=M, k0=3)
    kwargs.update(ALGO_SETUPS[key])
    kwargs.update(overrides)
    fed = FedConfig(**kwargs)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    return algo, state


def _assert_bitwise(res, ref):
    assert res.rounds_run == ref.rounds_run
    assert set(res.history) == set(ref.history)
    for k in ref.history:
        np.testing.assert_array_equal(res.history[k], ref.history[k],
                                      err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), f"state[{key!r}] diverged"


def _row_keys(base, rows):
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(rows, dtype=jnp.uint32))


# ---------------------------------------------------------------- codec units
def test_none_codec_is_identity():
    comp = NoneCompressor()
    assert comp.identity and not comp.stochastic
    u = jnp.asarray(np.random.default_rng(0).normal(size=(3, 16)), jnp.float32)
    assert comp.encode_decode(u) is u


def test_bf16_nearest_exact_on_representable_values():
    """Values with <= 8 significant mantissa bits (zeros included — the
    padded tail) round-trip bitwise; everything else lands within half a
    bf16 ulp (2^-8 relative)."""
    comp = Bf16Compressor()
    exact = jnp.asarray([[0.0, 1.0, -2.5, 0.375, 1024.0, 3.140625]],
                        jnp.float32)
    np.testing.assert_array_equal(np.asarray(comp.encode_decode(exact)),
                                  np.asarray(exact))
    u = jnp.asarray(
        np.random.default_rng(1).normal(size=(4, 64)) * 37.1, jnp.float32)
    err = np.abs(np.asarray(comp.encode_decode(u)) - np.asarray(u))
    assert (err <= 2.0 ** -8 * np.abs(np.asarray(u)) + 1e-30).all()


def test_bf16_stochastic_exact_on_lattice_and_bounded():
    """Stochastic rounding never moves a value already on the bf16
    lattice (its low 16 bits are zero — the noise cannot carry), and the
    error stays within one bf16 ulp (2^-7 relative)."""
    comp = Bf16Compressor(rounding="stochastic")
    assert comp.stochastic
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    lattice = jnp.broadcast_to(
        jnp.asarray([0.0, 1.0, -2.5, 1024.0], jnp.float32), (4, 4))
    np.testing.assert_array_equal(
        np.asarray(comp.encode_decode(lattice, keys=keys)),
        np.asarray(lattice))
    u = jnp.asarray(
        np.random.default_rng(2).normal(size=(4, 64)) * 5.3, jnp.float32)
    err = np.abs(np.asarray(comp.encode_decode(u, keys=keys)) - np.asarray(u))
    assert (err <= 2.0 ** -7 * np.abs(np.asarray(u)) + 1e-30).all()


@pytest.mark.parametrize("rounding,bound", [("nearest", 0.5),
                                            ("stochastic", 1.0)])
def test_int8_error_bounded_by_row_grid(rounding, bound):
    """|u - C(u)| <= bound * scale with scale = (max - min)/255 per row;
    a constant row (scale 0) decodes exactly."""
    comp = Int8Compressor(rounding=rounding)
    u = jnp.asarray(
        np.random.default_rng(3).normal(size=(5, 96)) * 11.0, jnp.float32)
    keys = _row_keys(jax.random.PRNGKey(1), 5) if comp.stochastic else None
    dec = np.asarray(comp.encode_decode(u, keys=keys))
    un = np.asarray(u)
    scale = (un.max(-1, keepdims=True) - un.min(-1, keepdims=True)) / 255.0
    assert (np.abs(dec - un) <= bound * scale * (1 + 1e-5)).all()
    const = jnp.full((2, 16), -3.75, jnp.float32)
    keys2 = _row_keys(jax.random.PRNGKey(2), 2) if comp.stochastic else None
    np.testing.assert_array_equal(
        np.asarray(comp.encode_decode(const, keys=keys2)), np.asarray(const))


def test_int8_stochastic_rounding_is_unbiased():
    """E[C(u)] = u: averaging decodes of the SAME row under many keys
    converges to the raw row (floor(t + U[0,1)) is unbiased)."""
    comp = Int8Compressor(rounding="stochastic")
    row = np.random.default_rng(4).normal(size=16).astype(np.float32)
    reps = 512
    u = jnp.broadcast_to(jnp.asarray(row), (reps, 16))
    keys = _row_keys(jax.random.PRNGKey(3), reps)
    mean = np.asarray(comp.encode_decode(u, keys=keys)).mean(0)
    scale = (row.max() - row.min()) / 255.0
    # CLT: the per-lane sampling error of the mean is ~ scale/sqrt(reps)
    assert np.abs(mean - row).max() < 5 * scale / np.sqrt(reps)


def test_topk_keeps_exactly_k_largest_lanes():
    comp = TopKCompressor(frac=0.25)
    u = jnp.asarray([[0.0, 5.0, -3.0, 1.0, 0.5, -0.25, 8.0, 0.0]],
                    jnp.float32)
    assert comp.k_for(8) == 2
    dec = np.asarray(comp.encode_decode(u, n=8))[0]
    expect = np.zeros(8, np.float32)
    expect[1], expect[6] = 5.0, 8.0  # the two largest-|.| lanes, exact
    np.testing.assert_array_equal(dec, expect)
    # k is computed on the LOGICAL lane count, floor 1, cap n
    assert TopKCompressor(frac=1e-6).k_for(400) == 1
    assert TopKCompressor(frac=1.0).k_for(400) == 400


def test_codec_wire_byte_model_exact():
    n = 400
    assert NoneCompressor().wire_bytes(n) == HEADER_BYTES + 4 * n == 1608
    assert Bf16Compressor().wire_bytes(n) == HEADER_BYTES + 2 * n == 808
    assert Int8Compressor().wire_bytes(n) == HEADER_BYTES + 8 + n == 416
    assert TopKCompressor(0.25).wire_bytes(n) == HEADER_BYTES + 8 * 100 == 808
    assert downlink_bytes(n) == HEADER_BYTES + 4 * n
    assert uplink_bytes(None, n) == NoneCompressor().wire_bytes(n)
    assert uplink_bytes(Int8Compressor(), n) == 416


def test_round_key_is_pure_and_round_dependent():
    rng = jax.random.PRNGKey(9)
    k3 = compress.round_key(rng, jnp.int32(3))
    np.testing.assert_array_equal(
        np.asarray(k3), np.asarray(compress.round_key(rng, jnp.int32(3))))
    assert not np.array_equal(
        np.asarray(k3), np.asarray(compress.round_key(rng, jnp.int32(4))))
    # fold_in, not split: the algorithm's rng stream never advances
    np.testing.assert_array_equal(np.asarray(rng),
                                  np.asarray(jax.random.PRNGKey(9)))


def test_factory_validation():
    with pytest.raises(ValueError, match="identity"):
        make_compressor("none", error_feedback=True)
    with pytest.raises(KeyError, match="gzip"):
        make_compressor("gzip")
    with pytest.raises(ValueError, match="rounding"):
        make_compressor("int8", rounding="truncate")
    with pytest.raises(ValueError, match="frac"):
        make_compressor("topk", topk_frac=0.0)
    with pytest.raises(ValueError, match="lossy"):
        compress.as_compressor(None, error_feedback=True)
    inst = Int8Compressor(error_feedback=True)
    assert compress.as_compressor(inst) is inst
    assert compress.as_compressor(None) is None


# ------------------------------------------------------- upload + EF residual
def _padded_spec():
    spec = pt.ravel_spec({"w": jnp.zeros((9,), jnp.float32)})
    assert spec.padded_size > spec.size  # lane-padded
    return spec


def test_compress_upload_re_zeros_padded_tail():
    """Affine int8 decodes 0 to lo + q*scale != 0; the upload hook forces
    the padded tail back to exact zero (RavelSpec invariant)."""
    spec = _padded_spec()
    r = np.random.default_rng(5)
    contrib = np.zeros((4, spec.padded_size), np.float32)
    contrib[:, :spec.size] = r.normal(size=(4, spec.size)) + 2.0
    dec, ef = api.compress_upload(Int8Compressor(rounding="nearest"),
                                  jnp.asarray(contrib), None, spec)
    assert ef is None
    dec = np.asarray(dec)
    assert (dec[:, spec.size:] == 0.0).all()
    assert np.abs(dec[:, :spec.size] - contrib[:, :spec.size]).max() < 0.1


@pytest.mark.parametrize("codec", [
    Bf16Compressor(error_feedback=True),
    Int8Compressor(error_feedback=True),
    TopKCompressor(0.25, error_feedback=True),
], ids=["bf16", "int8", "topk"])
def test_error_feedback_telescopes(codec):
    """Σ_r C(u_r) + e_R == Σ_r contrib_r: each round's codec error is
    carried, not lost — whatever the codec."""
    spec = _padded_spec()
    r = np.random.default_rng(6)
    base = jax.random.PRNGKey(11)
    ef = jnp.zeros((4, spec.padded_size), jnp.float32)
    total_dec = np.zeros((4, spec.padded_size), np.float64)
    total_raw = np.zeros((4, spec.padded_size), np.float64)
    for rnd in range(6):
        c = np.zeros((4, spec.padded_size), np.float32)
        c[:, :spec.size] = r.normal(size=(4, spec.size))
        dec, ef = api.compress_upload(
            codec, jnp.asarray(c), ef, spec,
            key=compress.round_key(base, jnp.int32(rnd)))
        total_dec += np.asarray(dec, np.float64)
        total_raw += c.astype(np.float64)
    np.testing.assert_allclose(total_dec + np.asarray(ef, np.float64),
                               total_raw, rtol=1e-5, atol=1e-5)
    # the residual's padded tail never becomes nonzero
    assert (np.asarray(ef)[:, spec.size:] == 0.0).all()


def test_error_feedback_freezes_masked_clients():
    spec = _padded_spec()
    r = np.random.default_rng(7)
    ef0 = np.zeros((4, spec.padded_size), np.float32)
    ef0[:, :spec.size] = r.normal(size=(4, spec.size))
    c = np.zeros((4, spec.padded_size), np.float32)
    c[:, :spec.size] = r.normal(size=(4, spec.size))
    mask = jnp.asarray([True, False, True, False])
    _, ef1 = api.compress_upload(
        TopKCompressor(0.25, error_feedback=True), jnp.asarray(c),
        jnp.asarray(ef0), spec, mask=mask)
    ef1 = np.asarray(ef1)
    np.testing.assert_array_equal(ef1[1], ef0[1])
    np.testing.assert_array_equal(ef1[3], ef0[3])
    assert not np.array_equal(ef1[0], ef0[0])


# --------------------------------------- compression="none" == plain, bitwise
@pytest.mark.parametrize("algo_key", FIVE)
@pytest.mark.parametrize("scan", [True, False], ids=["scan", "legacy"])
def test_none_bitwise_identical_dense(problem, algo_key, scan):
    """The engine resolves the identity codec (no EF) to "no compressor"
    before building the round fn — the same lowered program, so history
    AND state are bitwise equal, not merely close."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    ref = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK)
    res = run_rounds(algo, state, batch, ROUNDS, scan=scan, chunk_size=CHUNK,
                     compression="none")
    _assert_bitwise(res, ref)


@pytest.mark.parametrize("algo_key", FIVE)
def test_none_bitwise_identical_active_store(problem, algo_key):
    algo, state = _make(problem, algo_key)
    _, batch = problem
    kw = dict(participation=make_policy("uniform", M, 0.5, seed=3),
              store="active")
    ref = run_rounds(algo, state, batch, ROUNDS, **kw)
    res = run_rounds(algo, state, batch, ROUNDS, compression="none", **kw)
    _assert_bitwise(res, ref)


# ------------------------------------------------------- compressed runs
@pytest.mark.parametrize("kw", [
    dict(compression="bf16"),
    dict(compression="int8", error_feedback=True),
    dict(compression="topk", topk_frac=0.25, error_feedback=True),
], ids=["bf16", "int8+ef", "topk+ef"])
def test_compressed_run_engages_codec(problem, kw):
    """Lossy codecs actually change the trajectory (the plumbing is not
    silently dropping the compressor), stay finite, and carry the EF
    buffer in the returned state exactly when enabled."""
    algo, state = _make(problem, "fedgia_diag")
    _, batch = problem
    ref = run_rounds(algo, state, batch, ROUNDS)
    res = run_rounds(algo, state, batch, ROUNDS, **kw)
    assert np.isfinite(res.history["f_xbar"]).all()
    assert not np.array_equal(res.history["f_xbar"], ref.history["f_xbar"])
    assert ("ef" in res.state) == bool(kw.get("error_feedback"))


def test_compressed_legacy_matches_scan(problem):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    kw = dict(compression="topk", topk_frac=0.25, error_feedback=True,
              clock=ComputeClock(M, 1.0 + (np.arange(M) % 3),
                                 bandwidth_bps=1e4),
              max_staleness=2)
    ref = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK,
                     **kw)
    res = run_rounds(algo, state, batch, ROUNDS, scan=False, **kw)
    _assert_bitwise(res, ref)


@pytest.mark.parametrize("algo_key", ["fedgia_diag", "scaffold"])
def test_compressed_active_matches_dense(problem, algo_key):
    """Stochastic keys come from RESIDENT row ids, so the packed tile
    quantizes each client exactly as the dense round does; the EF
    gather/scatter is the dense mask freeze row for row."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    kw = dict(participation=make_policy("uniform", M, 0.5, seed=3),
              compression="int8", error_feedback=True)
    ref = run_rounds(algo, state, batch, ROUNDS, store="dense", **kw)
    res = run_rounds(algo, state, batch, ROUNDS, store="active", **kw)
    assert res.rounds_run == ref.rounds_run
    comparable = ("selected", "cr", "local_grad_evals")
    full = getattr(algo, "active_tile", "participants") == "population"
    for k in ref.history:
        if full or k in comparable:
            np.testing.assert_array_equal(res.history[k], ref.history[k],
                                          err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          res.state[key], ref.state[key])
        assert all(jax.tree.leaves(ok)), f"state[{key!r}] diverged"


def test_engine_compression_validation(problem):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    with pytest.raises(ValueError, match="flat"):
        run_rounds(algo, state, batch, 2, compression="int8", flat=False)
    with pytest.raises(ValueError, match="identity"):
        run_rounds(algo, state, batch, 2, compression="none",
                   error_feedback=True)
    with pytest.raises(ValueError, match="lossy"):
        run_rounds(algo, state, batch, 2, error_feedback=True)


# -------------------------------------------------------- byte-accurate clock
def test_clock_bandwidth_validation():
    with pytest.raises(ValueError, match="bandwidth"):
        ComputeClock(4, bandwidth_bps=-1.0)
    with pytest.raises(ValueError, match="bandwidth"):
        ComputeClock(4).with_wire(10, 10)


def test_byte_clock_goldens(problem):
    """Hand-computed wire accounting for an equal-speed fleet: every
    client arrives every round, so per round bytes_up = M * uplink,
    bytes_down = M * downlink, and rounds fire every
    compute_s + (uplink + downlink)/bandwidth simulated seconds."""
    _, batch = problem
    bw = 1.0e4
    n = N  # LeastSquares(N): the model is one (N,) weight vector
    for name, kw, wire_up in [
        ("none", dict(compression="none"), HEADER_BYTES + 4 * n),
        ("bf16", dict(compression="bf16"), HEADER_BYTES + 2 * n),
        ("int8", dict(compression="int8", error_feedback=True),
         HEADER_BYTES + 8 + n),
        ("topk", dict(compression="topk", topk_frac=0.25,
                      error_feedback=True), HEADER_BYTES + 8 * 5),
    ]:
        algo, state = _make(problem, "fedgia_diag")
        res = run_rounds(algo, state, batch, 6,
                         clock=ComputeClock(M, compute_s=1.0,
                                            bandwidth_bps=bw),
                         max_staleness=2, **kw)
        wire_down = HEADER_BYTES + 4 * n
        np.testing.assert_array_equal(
            res.history["bytes_up"], np.full(6, M * wire_up, np.float32),
            err_msg=name)
        np.testing.assert_array_equal(
            res.history["bytes_down"], np.full(6, M * wire_down, np.float32),
            err_msg=name)
        dur = 1.0 + (wire_up + wire_down) / bw
        np.testing.assert_allclose(res.history["sim_time"],
                                   dur * np.arange(6), rtol=1e-6,
                                   err_msg=name)


def test_byte_metrics_follow_arrivals(problem):
    """Heterogeneous speeds: per-round bytes are n_arrived * wire — the
    byte counters ride the same arrival mask as `selected`."""
    algo, state = _make(problem, "fedgia_diag")
    _, batch = problem
    speeds = np.where(np.arange(M) % 2 == 0, 1.0, 3.0)
    res = run_rounds(algo, state, batch, ROUNDS,
                     clock=ComputeClock(M, compute_s=speeds,
                                        bandwidth_bps=1.0e4),
                     max_staleness=8, compression="int8", error_feedback=True)
    up, down = HEADER_BYTES + 8 + N, HEADER_BYTES + 4 * N
    np.testing.assert_array_equal(res.history["bytes_up"],
                                  res.history["selected"] * up)
    np.testing.assert_array_equal(res.history["bytes_down"],
                                  res.history["selected"] * down)


def test_no_bandwidth_means_no_byte_metrics_and_bitwise_clock(problem):
    """`bandwidth_bps=None` (the default) is the PR-4/5 clock: no byte
    keys in the history, and the run is bitwise the pre-compression
    engine (the wire term is never materialized)."""
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    clk = lambda: ComputeClock(M, compute_s=1.0 + (np.arange(M) % 3))
    ref = run_rounds(algo, state, batch, ROUNDS, clock=clk(), max_staleness=2)
    res = run_rounds(algo, state, batch, ROUNDS, clock=clk(), max_staleness=2,
                     compression="none")
    assert "bytes_up" not in ref.history and "bytes_up" not in res.history
    _assert_bitwise(res, ref)


def test_wallclock_baseline_row_reproduced_bitwise():
    """The committed BENCH_wallclock.baseline.json rows must not move:
    re-running the benchmark's (fedgia_d, spread=4, uniform) cell with
    the compression-era engine reproduces cr / sim_time_s / obj exactly
    (simulated time is deterministic — any drift is an algorithmic
    change to the uncompressed clocked round)."""
    root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, root)
    try:
        from benchmarks.common import M_CLIENTS, make_problem
        from benchmarks.wallclock_bench import (ALGOS, K0, MAX_STALENESS,
                                                straggler_speeds)
    finally:
        sys.path.remove(root)
    with open(os.path.join(root, "benchmarks", "baselines",
                           "BENCH_wallclock.baseline.json")) as f:
        base = json.load(f)
    row = next(r for r in base["rows"]
               if r["algo"] == "fedgia_d" and r["spread"] == 4.0
               and r["weighting"] == "uniform")
    assert row["converged"], "baseline cell must be a converged run"
    model, batch, tol = make_problem("linreg", 0)
    fed = FedConfig(num_clients=M_CLIENTS, k0=K0, **ALGOS["fedgia_d"])
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)),
                      jax.random.PRNGKey(1), init_batch=batch)
    clk = ComputeClock(M_CLIENTS, straggler_speeds(M_CLIENTS, 4.0))
    res = run_rounds(algo, state, batch, base["max_rounds"], tol=tol,
                     clock=clk, max_staleness=MAX_STALENESS,
                     stale_weighting="uniform")
    assert res.stopped_early
    assert 2 * res.rounds_run == row["cr"]
    assert float(res.history["sim_time"][-1]) == row["sim_time_s"]
    assert float(res.history["f_xbar"][-1]) == row["obj"]


# ------------------------------------- sharded: ONE model-size all-reduce
_SHARDED_COMPRESSED_SCRIPT = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from hlo_guard import assert_barrier_round
    from repro.config import FedConfig
    from repro.core import api, compress, engine, make_algorithm, run_rounds
    from repro.data import linreg_noniid
    from repro.launch.mesh import make_host_mesh
    from repro.models import LeastSquares
    from repro.utils import pytree as pt

    m, n, d = 8, 24, 320
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, d, n, m).items()}
    model = LeastSquares(n)
    mesh = make_host_mesh(data=8)
    comp = compress.make_compressor("int8", error_feedback=True)

    def round_hlo(algo_name):
        fed = FedConfig(algorithm=algo_name, num_clients=m, k0=3, alpha=1.0,
                        sigma_t=0.3, h_policy="diag_ema", lr=0.01)
        algo = make_algorithm(fed, model.loss, model=model)
        s0 = algo.init(model.init(jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), init_batch=batch)
        spec = pt.ravel_spec(s0["x"])
        s0f = engine.flatten_state(algo, s0, spec)
        s0f["ef"] = jnp.zeros((m, spec.padded_size), spec.dtype)
        rf = engine.make_round_fn(algo, mesh, masked=True, flat_spec=spec,
                                  compressor=comp)
        st, b = engine.shard_inputs(algo, s0f, batch, mesh)
        args = (st, b, jnp.ones((m,), bool))
        return jax.jit(rf).lower(*args).compile().as_text()

    for name in ("fedgia", "fedavg", "fedprox", "fedpd", "scaffold"):
        assert_barrier_round(round_hlo(name), name)

    # the compressed sharded RUN matches the compressed single-device run:
    # per-client stochastic keys derive from GLOBAL row ids, so each
    # client draws the same rounding noise whatever the sharding
    fed = FedConfig(algorithm="fedgia", num_clients=m, k0=3, alpha=1.0,
                    sigma_t=0.3, h_policy="diag_ema")
    algo = make_algorithm(fed, model.loss, model=model)
    s0 = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                   init_batch=batch)
    kw = dict(compression="int8", error_feedback=True)
    ref = run_rounds(algo, s0, batch, 10, **kw)
    res = run_rounds(algo, s0, batch, 10, mesh=mesh, **kw)
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-4, atol=1e-6, err_msg=k)
    print("COMPRESSED_SHARDED_OK one model-size all-reduce for all five")
    """
)


def test_compressed_sharded_one_all_reduce_and_parity():
    """Decompress-before-reduce: the codec is shard-local encode+decode,
    so the compressed round still lowers to exactly ONE model-size
    all-reduce for ALL FIVE algorithms, and the sharded compressed run
    matches single-device."""
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_COMPRESSED_SCRIPT],
        env=fake_device_env(8), capture_output=True, text=True, timeout=900,
    )
    assert "COMPRESSED_SHARDED_OK" in out.stdout, out.stdout + out.stderr
