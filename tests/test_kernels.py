"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fedgia_update import (
    fedgia_update,
    fedgia_update_flat,
    fedgia_update_ref,
)
from repro.kernels.fedgia_update.kernel import LANES
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.rwkv6_scan import rwkv6_scan, rwkv6_scan_ref

RNG = np.random.default_rng(42)


# ------------------------------------------------------------- fedgia_update
@pytest.mark.parametrize("n", [64, 128, 1000, 40000])
@pytest.mark.parametrize("k0", [1, 4, 9])
@pytest.mark.parametrize("sel", [True, False])
def test_fedgia_update_matches_unrolled(n, k0, sel):
    xbar = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    g = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    pi = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    h = jnp.asarray(RNG.uniform(0.05, 3.0, n), jnp.float32)
    sigma = jnp.float32(0.7)
    ref = fedgia_update_ref(xbar, g, pi, h, jnp.asarray(sel), sigma, 8, k0=k0)
    out = fedgia_update(xbar, g, pi, h, sel, sigma, 8, k0=k0, interpret=True)
    for a, b, name in zip(out, ref, ("x", "pi", "z")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5,
            err_msg=f"{name} mismatch n={n} k0={k0} sel={sel}",
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedgia_update_dtypes(dtype):
    n = 512
    args = [jnp.asarray(RNG.standard_normal(n), dtype) for _ in range(3)]
    h = jnp.asarray(RNG.uniform(0.1, 1.0, n), dtype)
    sigma = jnp.float32(0.5)
    ref = fedgia_update_ref(*args, h, jnp.asarray(True), sigma, 4, k0=5)
    out = fedgia_update(*args, h, True, sigma, 4, k0=5, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    for a, b in zip(out, ref):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=tol, atol=tol
        )


@pytest.mark.parametrize(
    "n", [2 * LANES, 2 * LANES + 1, 3 * LANES - 1],
    ids=["mod0", "mod1", "modLANES-1"],
)
def test_fedgia_update_padding_edges(n):
    """N % LANES in {0, 1, LANES-1}: the ops-layer lane padding must be
    invisible — kernel (interpret) == unpadded jnp oracle."""
    xbar = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    g = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    pi = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    h = jnp.asarray(RNG.uniform(0.05, 3.0, n), jnp.float32)
    sigma = jnp.float32(0.6)
    ref = fedgia_update_ref(xbar, g, pi, h, jnp.asarray(True), sigma, 8, k0=4)
    out = fedgia_update(xbar, g, pi, h, True, sigma, 8, k0=4, interpret=True)
    for a, b, name in zip(out, ref, ("x", "pi", "z")):
        assert a.shape == (n,), name
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


@pytest.mark.parametrize("n", [LANES, LANES + 1, 2 * LANES - 1])
@pytest.mark.parametrize("k0", [1, 5])
def test_fedgia_update_batched_matches_ref(n, k0):
    """The batched (m, N) kernel — the flat engine's round update — equals
    the jnp oracle per client, mixed ADMM/GD branch selects, across the
    same padding edges."""
    m = 6
    xbar = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    g = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    pi = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    h = jnp.asarray(RNG.uniform(0.05, 3.0, (m, n)), jnp.float32)
    sel = jnp.asarray([True, False, True, True, False, True])
    sigma = jnp.float32(0.7)
    ref = fedgia_update_flat(xbar, g, pi, h, sel, sigma, m, k0=k0,
                             use_kernel=False)
    out = fedgia_update_flat(xbar, g, pi, h, sel, sigma, m, k0=k0,
                             use_kernel=True, interpret=True)
    for a, b, name in zip(out, ref, ("x", "pi", "z")):
        assert a.shape == (m, n), name
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def _donation_args(m=6, n=2 * LANES):
    xbar = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    g = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    pi = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    h = jnp.asarray(RNG.uniform(0.05, 3.0, (m, n)), jnp.float32)
    sel = jnp.asarray([True, False, True, True, False, True][:m])
    return xbar, g, pi, h, sel, jnp.float32(0.7), m


def test_fedgia_update_donated_bitwise_equals_undonated():
    """Donation aliases buffers; it must not change a single bit of the
    math (interpret mode on CPU; `+ 0` copies keep the originals alive
    for the comparison)."""
    xbar, g, pi, h, sel, sigma, m = _donation_args()
    ref = fedgia_update_flat(xbar, g, pi, h, sel, sigma, m, k0=3,
                             use_kernel=True, interpret=True, donate=False)
    out = fedgia_update_flat(xbar + 0, g + 0, pi + 0, h, sel, sigma, m,
                             k0=3, use_kernel=True, interpret=True,
                             donate=True)
    for a, b, name in zip(out, ref, ("x", "pi", "z")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_fedgia_update_donated_consumes_buffers():
    """The donated entry point genuinely consumes xbar/gbar/pi: a second
    call on the same (now-deleted) arrays must raise instead of silently
    reading stale memory."""
    from repro.kernels.fedgia_update import fedgia_update_batched_kernel_donated

    xbar, g, pi, h, sel, sigma, m = _donation_args()
    xb, gb, pb = xbar + 0, g + 0, pi + 0
    fedgia_update_batched_kernel_donated(xb, gb, pb, h, sel, sigma,
                                         jnp.int32(m), k0=3, interpret=True)
    with pytest.raises((RuntimeError, ValueError),
                       match="deleted|donated"):
        fedgia_update_batched_kernel_donated(xb, gb, pb, h, sel, sigma,
                                             jnp.int32(m), k0=3,
                                             interpret=True)


def test_fedgia_update_donated_memory_analysis_aliases():
    """`memory_analysis()` proof of the in-place contract: the donated
    program aliases all three (m, N) state streams onto its outputs
    (alias bytes == 3 * m * N * 4) and allocates NO extra temp relative
    to the undonated lowering of the same call."""
    from repro.kernels.fedgia_update import (
        fedgia_update_batched_kernel,
        fedgia_update_batched_kernel_donated,
    )

    xbar, g, pi, h, sel, sigma, m = _donation_args()
    n = xbar.shape[1]
    args = (xbar, g, pi, h, sel, sigma, jnp.int32(m))
    don = fedgia_update_batched_kernel_donated.lower(
        *args, k0=3, interpret=True).compile().memory_analysis()
    und = fedgia_update_batched_kernel.lower(
        *args, k0=3, interpret=True).compile().memory_analysis()
    assert don.alias_size_in_bytes == 3 * m * n * 4
    assert und.alias_size_in_bytes == 0
    assert don.temp_size_in_bytes <= und.temp_size_in_bytes


def test_fedgia_update_flat_donate_falls_back_when_padded():
    """A ragged N forces a lane-padding copy, which would break the alias
    — ops.py must silently route donate=True through the undonated
    kernel (correct results, originals still alive)."""
    m, n = 4, LANES + 3
    xbar = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    g = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    pi = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    h = jnp.asarray(RNG.uniform(0.1, 2.0, (m, n)), jnp.float32)
    sel = jnp.asarray([True, True, False, True])
    sigma = jnp.float32(0.5)
    ref = fedgia_update_flat(xbar, g, pi, h, sel, sigma, m, k0=2,
                             use_kernel=True, interpret=True, donate=False)
    out = fedgia_update_flat(xbar, g, pi, h, sel, sigma, m, k0=2,
                             use_kernel=True, interpret=True, donate=True)
    for a, b in zip(out, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the originals survived (no donation happened on the padded path)
    assert np.isfinite(np.asarray(xbar)).all()


def test_fedgia_update_batched_rowwise_equals_single():
    """Each row of the batched kernel equals the single-vector kernel on
    that client's slice (same interpret-mode lowering, same math)."""
    m, n = 4, 2 * LANES
    xbar = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    g = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    pi = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    h = jnp.asarray(RNG.uniform(0.1, 2.0, (m, n)), jnp.float32)
    sel = jnp.asarray([True, False, True, False])
    sigma = jnp.float32(0.4)
    batched = fedgia_update_flat(xbar, g, pi, h, sel, sigma, m, k0=3,
                                 use_kernel=True, interpret=True)
    for i in range(m):
        single = fedgia_update(xbar[i], g[i], pi[i], h[i], bool(sel[i]),
                               sigma, m, k0=3, interpret=True)
        for a, b, name in zip(batched, single, ("x", "pi", "z")):
            np.testing.assert_allclose(np.asarray(a[i]), np.asarray(b),
                                       rtol=1e-6, atol=1e-6,
                                       err_msg=f"client {i} {name}")


# ------------------------------------------------------------ flash_attention
@pytest.mark.parametrize(
    "B,H,Kv,S,hd,window,bq,bk",
    [
        (2, 4, 4, 128, 64, None, 64, 64),
        (1, 8, 2, 200, 64, None, 64, 64),   # GQA, unaligned seq
        (2, 4, 1, 192, 128, None, 128, 64), # MQA
        (1, 4, 4, 256, 64, 64, 64, 64),     # sliding window
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Kv, S, hd, window, bq, bk, dtype):
    q = jnp.asarray(RNG.standard_normal((B, H, S, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Kv, S, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Kv, S, hd)), dtype)
    ref = flash_attention_ref(q, k, v, window=window)
    out = flash_attention(q, k, v, window=window, interpret=True,
                          block_q=bq, block_k=bk)
    tol = 2e-5 if dtype == jnp.float32 else 2.5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_matches_model_blocked_softmax():
    """The kernel and models/attention.blocked_attention agree (same oracle)."""
    from repro.models.attention import blocked_attention

    B, H, Kv, S, hd = 1, 4, 2, 96, 32
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, Kv, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, Kv, hd)), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = blocked_attention(q, k, v, pos, pos, block_k=32)
    out = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        interpret=True, block_q=32, block_k=32,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- rwkv6_scan
@pytest.mark.parametrize(
    "B,H,T,hd,bt",
    [(2, 3, 64, 32, 32), (1, 4, 100, 64, 64), (2, 2, 128, 64, 16)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_sweep(B, H, T, hd, bt, dtype):
    r = jnp.asarray(RNG.standard_normal((B, H, T, hd)) * 0.5, dtype)
    k = jnp.asarray(RNG.standard_normal((B, H, T, hd)) * 0.5, dtype)
    v = jnp.asarray(RNG.standard_normal((B, H, T, hd)) * 0.5, dtype)
    w = jnp.asarray(RNG.uniform(0.85, 0.999, (B, H, T, hd)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, hd)) * 0.5, jnp.float32)
    yr, sr = rwkv6_scan_ref(r, k, v, w, u)
    yk, sk = rwkv6_scan(r, k, v, w, u, interpret=True, block_t=bt)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(yk, np.float32), np.asarray(yr, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-4, atol=1e-3)


def test_rwkv6_state_carry_is_chunk_invariant():
    """Final state must not depend on the chunk size."""
    B, H, T, hd = 1, 2, 96, 32
    r, k, v = (jnp.asarray(RNG.standard_normal((B, H, T, hd)) * 0.3, jnp.float32)
               for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.9, 0.999, (B, H, T, hd)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, hd)) * 0.3, jnp.float32)
    _, s16 = rwkv6_scan(r, k, v, w, u, interpret=True, block_t=16)
    _, s48 = rwkv6_scan(r, k, v, w, u, interpret=True, block_t=48)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s48), rtol=1e-5, atol=1e-5)
