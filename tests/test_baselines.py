"""Comparison baselines: each must make progress on the paper's Example V.1
and FedGiA must use fewer rounds than FedAvg (Table IV's headline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig
from repro.core import make_algorithm
from repro.data import linreg_noniid
from repro.models import LeastSquares

M, N, D = 8, 20, 400


@pytest.fixture(scope="module")
def problem():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    return LeastSquares(N), batch


def rounds_to_tol(problem, algo_name, tol=1e-6, max_rounds=1500, **kw):
    model, batch = problem
    fed = FedConfig(algorithm=algo_name, num_clients=M, k0=5, alpha=1.0, **kw)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                      init_batch=batch)
    rnd = jax.jit(algo.round)
    first = last = None
    for r in range(max_rounds):
        state, met = rnd(state, batch)
        if first is None:
            first = float(met["f_xbar"])
        last = (float(met["f_xbar"]), float(met["grad_sq_norm"]))
        if last[1] < tol:
            return r + 1, first, last
    return max_rounds, first, last


@pytest.mark.parametrize(
    "algo,kw",
    [
        ("fedavg", dict(lr=0.01)),
        ("fedprox", dict(lr=0.002)),
        ("fedpd", dict(lr=0.05, fedpd_eta=1.0)),
        ("scaffold", dict(lr=0.01)),
    ],
)
def test_baseline_decreases_objective(problem, algo, kw):
    rounds, first, last = rounds_to_tol(problem, algo, tol=1e-6, max_rounds=400, **kw)
    assert last[0] < first, f"{algo}: no objective decrease {first} -> {last[0]}"
    assert last[1] < 1e-1, f"{algo}: gradient did not shrink: {last}"


def test_fedgia_fewer_rounds_than_fedavg(problem):
    """Paper Table IV: FedGiA's CR are an order of magnitude below FedAvg's."""
    r_gia, _, l_gia = rounds_to_tol(
        problem, "fedgia", tol=1e-8, sigma_t=0.2, h_policy="scalar"
    )
    r_avg, _, l_avg = rounds_to_tol(problem, "fedavg", tol=1e-8, lr=0.01)
    assert l_gia[1] < 1e-8
    assert r_gia * 5 < r_avg, f"FedGiA {r_gia} rounds vs FedAvg {r_avg}"


def test_all_algorithms_agree_on_optimum(problem):
    """Every algorithm drives f to the same value (paper: identical Obj.)."""
    model, batch = problem
    finals = {}
    for algo_name, kw in [
        ("fedgia", dict(sigma_t=0.2)),
        ("fedavg", dict(lr=0.01)),
        ("scaffold", dict(lr=0.01)),
    ]:
        _, _, last = rounds_to_tol(problem, algo_name, tol=1e-9,
                                   max_rounds=1500, **kw)
        finals[algo_name] = last[0]
    vals = list(finals.values())
    assert max(vals) - min(vals) < 1e-4, finals
