"""Unit tests for the FedGiA algorithm core (paper Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig
from repro.core import FedGiA, make_algorithm
from repro.core.selection import num_selected, selection_mask
from repro.data import linreg_noniid
from repro.models import LeastSquares
from repro.utils import pytree as pt

M, N, D = 8, 20, 400


@pytest.fixture(scope="module")
def problem():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    model = LeastSquares(N)
    return model, batch


def make_algo(problem, **kw):
    model, batch = problem
    defaults = dict(
        algorithm="fedgia", num_clients=M, k0=5, alpha=0.5, sigma_t=0.2,
        h_policy="scalar", collapsed=True,
    )
    defaults.update(kw)
    fed = FedConfig(**defaults)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                      init_batch=batch)
    return algo, state


def test_collapsed_equals_unrolled(problem):
    """DESIGN §6 B1: the closed-form round is EXACTLY the k0-step iteration."""
    model, batch = problem
    for k0 in (1, 3, 10):
        algo_c, s_c = make_algo(problem, collapsed=True, k0=k0)
        algo_u, s_u = make_algo(problem, collapsed=False, k0=k0)
        for _ in range(3):
            s_c, _ = algo_c.round(s_c, batch)
            s_u, _ = algo_u.round(s_u, batch)
        np.testing.assert_allclose(s_c["z"]["x"], s_u["z"]["x"], rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(s_c["pi"]["x"], s_u["pi"]["x"], rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("h_policy", ["scalar", "diag_ema"])
@pytest.mark.parametrize("sigma_t", [0.15, 0.6, 6.0])
@pytest.mark.parametrize("k0", [1, 2, 7])
def test_collapsed_equals_unrolled_grid(problem, k0, sigma_t, h_policy):
    """Deterministic (hypothesis-free) coverage of the collapse invariant
    across the (k0, sigma_t, h_policy) grid — the guarantee holds for any
    elementwise H, not just the scalar policy the legacy test exercised."""
    model, batch = problem
    algo_c, s_c = make_algo(problem, collapsed=True, k0=k0, sigma_t=sigma_t,
                            h_policy=h_policy)
    algo_u, s_u = make_algo(problem, collapsed=False, k0=k0, sigma_t=sigma_t,
                            h_policy=h_policy)
    for _ in range(3):
        s_c, met_c = algo_c.round(s_c, batch)
        s_u, met_u = algo_u.round(s_u, batch)
    np.testing.assert_allclose(s_c["z"]["x"], s_u["z"]["x"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(s_c["pi"]["x"], s_u["pi"]["x"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(s_c["x"]["x"], s_u["x"]["x"], rtol=1e-5, atol=1e-6)
    if h_policy == "diag_ema":
        np.testing.assert_allclose(s_c["h"]["x"], s_u["h"]["x"],
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(met_c["f_xbar"]), float(met_u["f_xbar"]),
                               rtol=1e-6)


def test_gd_branch_equations(problem):
    """eqs (15)-(17): non-selected clients get x=x̄, pi=-ḡ, z=x̄-ḡ/σ."""
    model, batch = problem
    algo, state = make_algo(problem, alpha=1e-9)  # select 1, rest GD
    xbar = pt.tree_mean_over_axis(state["z"], axis=0)
    grads = jax.vmap(jax.grad(lambda p, b: model.loss(p, b)[0]), (None, 0))(
        xbar, batch
    )
    gbar = pt.tree_scale(grads, 1.0 / M)
    new_state, _ = algo.round(state, batch)
    sigma = float(state["sigma"])
    # at least M-1 clients took the GD branch
    gd_pi = -gbar["x"]
    matches = np.isclose(
        np.asarray(new_state["pi"]["x"]), np.asarray(gd_pi), rtol=1e-5, atol=1e-7
    ).all(axis=1)
    assert matches.sum() >= M - 1
    gd_z = np.asarray(xbar["x"])[None] - np.asarray(gbar["x"]) / sigma
    z_match = np.isclose(
        np.asarray(new_state["z"]["x"]), gd_z, rtol=1e-5, atol=1e-7
    ).all(axis=1)
    assert z_match.sum() >= M - 1


def test_aggregation_is_mean_of_z(problem):
    model, batch = problem
    algo, state = make_algo(problem)
    new_state, _ = algo.round(state, batch)
    xbar = np.asarray(pt.tree_mean_over_axis(state["z"], axis=0)["x"])
    np.testing.assert_allclose(np.asarray(new_state["x"]["x"]), xbar, rtol=1e-6)


def test_client_params_derivation(problem):
    """x_i = z_i - pi_i/sigma (eq. 14 inverted) — B3: x never stored."""
    model, batch = problem
    algo, state = make_algo(problem)
    state, _ = algo.round(state, batch)
    xc = algo.client_params(state)
    recon = pt.tree_axpy(1.0 / state["sigma"], state["pi"], xc)
    np.testing.assert_allclose(
        np.asarray(recon["x"]), np.asarray(state["z"]["x"]), rtol=1e-5, atol=1e-6
    )


def test_sigma_satisfies_theory(problem):
    """init with sigma_t >= 6 gives the guaranteed regime sigma >= 6r/m."""
    algo, state = make_algo(problem, sigma_t=6.0)
    assert float(state["sigma"]) >= 6.0 * float(state["r"]) / M - 1e-6


def test_selection_mask_counts():
    for alpha in (0.1, 0.5, 1.0):
        mask = selection_mask(jax.random.PRNGKey(0), 16, alpha)
        assert int(mask.sum()) == num_selected(16, alpha)
    # different rounds give different subsets
    m1 = selection_mask(jax.random.PRNGKey(1), 64, 0.5)
    m2 = selection_mask(jax.random.PRNGKey(2), 64, 0.5)
    assert (np.asarray(m1) != np.asarray(m2)).any()


def test_gram_policy_matches_scalar_limit(problem):
    """With H = Gram and with H = rI the fixed point is the same (both are
    valid inexact-ADMM preconditioners): both converge to the same optimum."""
    model, batch = problem
    results = {}
    for hp in ("scalar", "gram"):
        algo, state = make_algo(problem, h_policy=hp, alpha=1.0,
                                collapsed=(hp == "scalar"))
        rnd = jax.jit(algo.round)
        for _ in range(300):
            state, met = rnd(state, batch)
        results[hp] = np.asarray(state["x"]["x"])
        assert float(met["grad_sq_norm"]) < 1e-8
    np.testing.assert_allclose(results["scalar"], results["gram"], rtol=1e-3, atol=1e-4)


def test_metrics_cr_accounting(problem):
    model, batch = problem
    algo, state = make_algo(problem)
    state, met = algo.round(state, batch)
    assert float(met["cr"]) == 2.0  # 2 communications (up+down) per round
    state, met = algo.round(state, batch)
    assert float(met["cr"]) == 4.0
    assert float(met["local_grad_evals"]) == 1.0  # C2: ONE grad per round
