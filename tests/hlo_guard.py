"""Shared HLO collective-budget guard for the sharded subprocess tests.

Every sharded integration test asserts the round's collective budget by
lowering the round fn and counting MODEL-SIZE collectives in the compiled
HLO text. Before this module each test carried its own copy of the regex;
now they all share one classifier:

  * `collective_counts(txt)` — {kind: count} over all-reduce /
    reduce-scatter / all-gather ops whose result shape is array-like
    (model-size), skipping the scalar/tuple-of-scalar riders.
  * `assert_barrier_round(txt)` — the PR-5 contract: eq. (11) + all
    diagnostics ride exactly ONE model-size all-reduce, and the round
    issues no model-size reduce-scatter/all-gather.
  * `assert_overlap_round(txt)` — the overlap="scatter" contract: ZERO
    model-size all-reduces; the round's model-size traffic is exactly one
    reduce-scatter (this round's contribution, issued early) plus one
    all-gather (last round's consensus shard, consumed at the top).

This module is imported both by the pytest process and INSIDE the
subprocess scripts (fake 8-device runs), so `conftest.fake_device_env`
puts the tests directory on the subprocess PYTHONPATH.

"Model-size" = the HLO result shape contains a dimensioned array
(`[<digit>` somewhere in the shape string). The scalar psum riders
(loss mean, |g|^2, participant count) lower to `f32[]` tuples and are
deliberately NOT counted — the guard is about wire traffic proportional
to the model, not O(1) control scalars.
"""
from __future__ import annotations

import re

KINDS = ("all-reduce", "reduce-scatter", "all-gather")

# `= <shape> <kind>(` — the result shape is either a bare `f32[...]` term
# or a tuple `(f32[...], f32[...])` for multi-operand collectives.
_COLLECTIVE_RE = re.compile(
    r"= ((?:\([^)]*\))|\S+) (all-reduce|reduce-scatter|all-gather)\(")


def is_model_size(shape: str) -> bool:
    """True when the HLO result shape string holds at least one
    dimensioned array (e.g. `f32[8,320]`), False for scalars (`f32[]`)
    and tuples of scalars."""
    return re.search(r"\[\d", shape) is not None


def collective_counts(txt: str, *, model_size_only: bool = True) -> dict:
    """Count collectives by kind in compiled HLO text.

    With `model_size_only` (default) only ops whose result shape carries a
    dimensioned array are counted — the scalar riders are free."""
    counts = {k: 0 for k in KINDS}
    for shape, kind in _COLLECTIVE_RE.findall(txt):
        if model_size_only and not is_model_size(shape):
            continue
        counts[kind] += 1
    return counts


def model_size_all_reduces(txt: str) -> int:
    """The historical single-number guard: model-size all-reduce count."""
    return collective_counts(txt)["all-reduce"]


def assert_barrier_round(txt: str, label: str = "") -> None:
    """The one-psum round (PR-5): exactly ONE model-size all-reduce, no
    all-gather. XLA additionally lowers the shard-local diagnostics
    reduction to at most one small reduce-scatter (result is 1/shards of
    the model) — tolerated, it predates the overlap work and is not a
    second model-size transfer."""
    c = collective_counts(txt)
    ok = (c["all-reduce"] == 1 and c["all-gather"] == 0
          and c["reduce-scatter"] <= 1)
    assert ok, (
        f"barrier round collective budget violated"
        f"{' (' + label + ')' if label else ''}: {c}")


def assert_overlap_round(txt: str, label: str = "") -> None:
    """The overlapped round: ZERO model-size all-reduces; one
    reduce-scatter (contribution, early) + one all-gather (consensus
    shard, deferred to the round top)."""
    c = collective_counts(txt)
    assert c == {"all-reduce": 0, "reduce-scatter": 1, "all-gather": 1}, (
        f"overlap round collective budget violated{' (' + label + ')' if label else ''}: {c}")
