"""Unit tests for core/selection.py: num_selected edge cases, mask
cardinality/determinism under fixed keys, and the policy-specific
properties of each ParticipationPolicy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection
from repro.core.selection import (
    AvailabilityParticipation,
    CyclicParticipation,
    ParticipationPolicy,
    UniformParticipation,
    WeightedParticipation,
    make_policy,
    num_selected,
    selection_mask,
)


# ------------------------------------------------------------ num_selected
@pytest.mark.parametrize(
    "m,alpha,expect",
    [
        (8, 0.0, 1),      # alpha -> 0 clamps to one client
        (8, 1e-9, 1),
        (8, 1.0, 8),      # alpha -> 1 selects everyone
        (8, 2.0, 8),      # clamped above
        (1, 0.0, 1),      # m = 1: the single client always runs
        (1, 1.0, 1),
        (8, 0.5, 4),
        (128, 0.1, 13),   # round(12.8)
        (10, 0.25, 2),    # banker's rounding of 2.5
    ],
)
def test_num_selected(m, alpha, expect):
    assert num_selected(m, alpha) == expect


# ---------------------------------------------------------- selection_mask
@pytest.mark.parametrize("m,alpha", [(8, 0.5), (8, 0.25), (7, 0.4), (1, 0.5)])
def test_mask_cardinality(m, alpha):
    mask = selection_mask(jax.random.PRNGKey(0), m, alpha)
    assert mask.shape == (m,) and mask.dtype == jnp.bool_
    assert int(mask.sum()) == num_selected(m, alpha)


def test_mask_deterministic_under_fixed_key():
    key = jax.random.PRNGKey(42)
    a = np.asarray(selection_mask(key, 16, 0.5))
    b = np.asarray(selection_mask(key, 16, 0.5))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(selection_mask(jax.random.PRNGKey(43), 16, 0.5))
    assert not np.array_equal(a, c)  # different key -> different draw (whp)


def test_mask_alpha_one_is_static_ones():
    mask = selection_mask(jax.random.PRNGKey(0), 8, 1.0)
    np.testing.assert_array_equal(np.asarray(mask), np.ones(8, bool))


# --------------------------------------------------------------- policies
def _roll(policy, rounds):
    """Materialise `rounds` masks the way the engine does."""
    ps = policy.init()
    masks = []
    for r in range(rounds):
        mask, ps = policy.mask(ps, jnp.int32(r))
        masks.append(np.asarray(mask))
    return np.stack(masks)


def test_base_policy_is_full_participation():
    masks = _roll(ParticipationPolicy(6), 3)
    np.testing.assert_array_equal(masks, np.ones((3, 6), bool))


def test_uniform_cardinality_and_determinism():
    pol = UniformParticipation(8, 0.5, seed=3)
    masks = _roll(pol, 12)
    assert masks.shape == (12, 8)
    np.testing.assert_array_equal(masks.sum(axis=1), 4)
    # same seed -> identical sequence; the policy state is the only RNG
    np.testing.assert_array_equal(masks, _roll(UniformParticipation(8, 0.5, seed=3), 12))
    # draws vary across rounds (12 identical rounds is ~impossible)
    assert any(not np.array_equal(masks[0], mk) for mk in masks[1:])
    # different seed -> different sequence
    assert not np.array_equal(masks, _roll(UniformParticipation(8, 0.5, seed=4), 12))


def test_uniform_is_uniform_over_clients():
    masks = _roll(UniformParticipation(8, 0.25, seed=0), 400)
    freq = masks.mean(axis=0)
    np.testing.assert_allclose(freq, 0.25, atol=0.08)


def test_weighted_cardinality_and_bias():
    m = 8
    weights = np.array([1, 1, 1, 1, 1, 1, 1, 20.0])
    pol = WeightedParticipation(m, 0.25, weights, seed=0)
    masks = _roll(pol, 300)
    np.testing.assert_array_equal(masks.sum(axis=1), 2)
    freq = masks.mean(axis=0)
    # the heavy client participates in (nearly) every round, the light
    # ones share the remaining slot
    assert freq[-1] > 0.9
    assert freq[:-1].max() < 0.5
    np.testing.assert_array_equal(
        masks, _roll(WeightedParticipation(m, 0.25, weights, seed=0), 300)
    )


def test_weighted_alpha_one_selects_all():
    masks = _roll(WeightedParticipation(4, 1.0, np.arange(1.0, 5.0)), 3)
    np.testing.assert_array_equal(masks, np.ones((3, 4), bool))


def test_cyclic_blocks_and_coverage():
    m, alpha = 8, 0.25  # |C| = 2 -> 4-round cycle
    pol = CyclicParticipation(m, alpha)
    masks = _roll(pol, 8)
    np.testing.assert_array_equal(masks.sum(axis=1), 2)
    # round 0 selects clients {0,1}, round 1 {2,3}, ...
    np.testing.assert_array_equal(np.nonzero(masks[0])[0], [0, 1])
    np.testing.assert_array_equal(np.nonzero(masks[1])[0], [2, 3])
    # every client participates exactly once per 4-round cycle
    np.testing.assert_array_equal(masks[:4].sum(axis=0), np.ones(m))
    np.testing.assert_array_equal(masks[4:].sum(axis=0), np.ones(m))
    # stateless: the mask is a pure function of the round index
    np.testing.assert_array_equal(
        np.asarray(pol.mask((), jnp.int32(1))[0]), masks[1]
    )


def test_cyclic_wraparound_block():
    # m=6, |C|=4: round 1 starts at client 4 and wraps to {4,5,0,1}
    masks = _roll(CyclicParticipation(6, 4 / 6), 2)
    np.testing.assert_array_equal(np.nonzero(masks[1])[0], [0, 1, 4, 5])


def test_availability_replays_trace_and_wraps():
    trace = np.array([[1, 0, 1], [0, 1, 0]], bool)
    pol = AvailabilityParticipation(3, trace)
    masks = _roll(pol, 4)
    np.testing.assert_array_equal(masks[0], trace[0])
    np.testing.assert_array_equal(masks[1], trace[1])
    np.testing.assert_array_equal(masks[2], trace[0])  # t mod T
    np.testing.assert_array_equal(masks[3], trace[1])


def test_availability_dead_round_falls_back_to_full():
    trace = np.array([[0, 0, 0], [1, 0, 0]], bool)
    masks = _roll(AvailabilityParticipation(3, trace), 2)
    np.testing.assert_array_equal(masks[0], np.ones(3, bool))
    np.testing.assert_array_equal(masks[1], trace[1])


def test_availability_from_dropout_reproducible():
    a = AvailabilityParticipation.from_dropout(8, 0.3, 32, seed=5)
    b = AvailabilityParticipation.from_dropout(8, 0.3, 32, seed=5)
    np.testing.assert_array_equal(np.asarray(a.trace), np.asarray(b.trace))
    # drop rate lands near drop_prob
    rate = 1.0 - np.asarray(a.trace).mean()
    assert 0.15 < rate < 0.45


# ---------------------------------------------------------------- factory
def test_make_policy_kinds():
    assert make_policy("full", 8) is None
    assert isinstance(make_policy("uniform", 8, 0.5), UniformParticipation)
    assert isinstance(make_policy("weighted", 8, 0.5), WeightedParticipation)
    assert isinstance(make_policy("cyclic", 8, 0.5), CyclicParticipation)
    assert isinstance(
        make_policy("straggler", 8, drop_prob=0.1, horizon=16),
        AvailabilityParticipation,
    )
    assert isinstance(
        make_policy("periodic", 8, periods=[1, 2, 3, 4, 1, 2, 3, 4],
                    horizon=16),
        AvailabilityParticipation,
    )
    with pytest.raises(KeyError):
        make_policy("nope", 8)
    assert set(selection.POLICIES) == {
        "full", "uniform", "weighted", "cyclic", "straggler", "periodic"
    }
