"""Cross-flag validation of the training CLI (launch/train.py).

`validate_flags` is the single place the engine knobs are checked against
each other, callable on a parsed Namespace without building a problem —
these tests pin every rejection (SystemExit) and the resolved knobs for
the accepted combinations (e.g. --clock implying async rounds).
"""
import pytest

from repro.launch.train import build_parser, validate_flags

BASE = ["--problem", "linreg", "--clients", "8", "--rounds", "4"]


def _args(*extra):
    return build_parser().parse_args(BASE + list(extra))


@pytest.mark.parametrize("argv,match", [
    # async-family flags need the async engine (or a clock, which implies it)
    (["--max-staleness", "2"], "--max-staleness requires --async"),
    (["--stale-weighting", "poly"], "--stale-weighting requires --async"),
    # per-client list flags need their owning mode
    (["--arrival-periods", "1,2,3,4,1,2,3,4"],
     "--arrival-periods requires --participation periodic"),
    (["--participation", "straggler", "--arrival-periods", "1,2,3,4,1,2,3,4"],
     "--arrival-periods requires --participation periodic"),
    (["--client-weights", "1,1,1,1,1,1,1,1"],
     "--client-weights requires --participation weighted"),
    (["--client-speeds", "1,2,3,4,1,2,3,4"],
     "--client-speeds requires --clock"),
    # the clock derives the arrival mask; a sampled policy conflicts
    (["--clock", "constant", "--participation", "periodic"],
     "cannot be combined with --participation"),
    # the trace clock needs a duration table the CLI cannot carry
    (["--clock", "trace"], "library-level"),
    # a negative decay would upweight the stalest anchors
    (["--clock", "constant", "--stale-weighting", "poly",
      "--stale-decay", "-1.0"], "--stale-decay must be > 0"),
    # list-length mismatches
    (["--participation", "periodic", "--arrival-periods", "1,2"],
     "--arrival-periods needs 8 values"),
    (["--participation", "weighted", "--client-weights", "1,2,3"],
     "--client-weights needs 8 values"),
    (["--clock", "constant", "--client-speeds", "1.5"],
     "--client-speeds needs 8 values"),
    # chunk autotuning tunes the (unsharded) scan chunk length
    (["--chunk", "fastest"], "--chunk must be an integer or 'auto'"),
    (["--chunk", "auto", "--no-scan"], "cannot be combined with --no-scan"),
    (["--chunk", "auto", "--shard-clients", "4"],
     "pass a fixed --chunk with"),
    # the kernel lives on the flat round path
    (["--kernel", "on", "--no-flat"], "requires the flat round path"),
    (["--kernel", "interpret", "--no-flat"], "requires the flat round path"),
    # the active-set store packs the FLAT buffers of the round's
    # participants — it needs the flat path and a participant source
    (["--store", "active", "--no-flat"],
     "--store active packs the flat"),
    (["--store", "active"], "--store active needs a per-round participant"),
    # the offload store is the single-device host/device split and runs a
    # host-driven loop: no sharding, no overlap carry slot, no scan chunks
    (["--store", "offload", "--no-flat"],
     "--store offload packs the flat"),
    (["--store", "offload"], "--store offload needs a per-round participant"),
    (["--store", "offload", "--participation", "uniform",
      "--shard-clients", "4"], "single-device host/device split"),
    (["--store", "offload", "--participation", "uniform",
      "--overlap", "scatter"], "does not ride it"),
    (["--store", "offload", "--participation", "uniform",
      "--chunk", "auto"], "has no chunks"),
    # the packed aggregate sums a participant tile — dense store has none
    (["--aggregate", "packed"], "requires --store active or --store offload"),
    # codecs run on the flat comm buffer; EF needs a lossy codec to carry
    # a residual for; topk-frac belongs to topk and must be a fraction
    (["--compression", "int8", "--no-flat"],
     "--compression runs on the flat"),
    (["--error-feedback"], "needs a lossy --compression"),
    (["--error-feedback", "--compression", "none"],
     "needs a lossy --compression"),
    (["--topk-frac", "0.5"], "--topk-frac requires --compression topk"),
    (["--compression", "int8", "--topk-frac", "0.5"],
     "--topk-frac requires --compression topk"),
    (["--compression", "topk", "--topk-frac", "0.0"],
     "--topk-frac must be in"),
    (["--compression", "topk", "--topk-frac", "1.5"],
     "--topk-frac must be in"),
    # byte-accurate comm time needs a positive rate and a clock to price
    (["--clock", "constant", "--bandwidth-bps", "-4000"],
     "--bandwidth-bps must be > 0"),
    (["--bandwidth-bps", "4000"], "--bandwidth-bps prices the wire"),
    # the overlap carry slot lives on the flat buffers; pods subdivide
    # the sharded client axis
    (["--overlap", "scatter", "--no-flat"],
     "--overlap scatter carries the reduce-scattered"),
    (["--pod", "2"], "--pod .* requires --shard-clients"),
    (["--pod", "3", "--shard-clients", "8"], "must be divisible by"),
    # fault injection corrupts (and screening filters) the flat buffer
    (["--faults", "bitflip"], "unknown kind"),
    (["--faults", "crash", "--no-flat"], "--faults corrupts the flat"),
    (["--screening", "--no-flat"], "--screening filters the flat"),
    # fault rates belong to --faults: broadcast-or-per-kind, in [0, 1]
    (["--fault-rate", "0.1"], "--fault-rate is the injection probability"),
    (["--faults", "crash", "--fault-rate", "0.1,0.2"],
     "--fault-rate needs 1 or 1 values"),
    (["--faults", "crash", "--fault-rate", "1.5"], "values must be in"),
    (["--faults", "crash", "--fault-rate", "lots"], "--fault-rate:"),
    # the norm clip is a screening knob and must be positive
    (["--clip-norm", "5"], "pass --screening"),
    (["--screening", "--clip-norm", "-1"], "--clip-norm must be > 0"),
    # quorum needs something that can withhold uploads, and fits [1, m]
    (["--quorum", "2"], "needs a source of non-arrival"),
    (["--participation", "uniform", "--quorum", "9"],
     "--quorum must be in"),
    # the deadline cuts SIMULATED rounds and can close them empty
    (["--deadline-s", "2.5"], "requires --clock"),
    (["--clock", "constant", "--deadline-s", "-1"],
     "--deadline-s must be > 0"),
    (["--clock", "constant", "--deadline-s", "2.5"], "pass .*--quorum"),
    # watchdog tuning knobs need the watchdog; offload can't host it
    (["--watchdog-patience", "2"], "pass --watchdog"),
    (["--watchdog-factor", "3.0"], "pass --watchdog"),
    (["--watchdog", "--watchdog-patience", "0"],
     "--watchdog-patience must be >= 1"),
    (["--watchdog", "--watchdog-factor", "1.0"], "RELATIVE to"),
    (["--watchdog", "--store", "offload", "--participation", "uniform"],
     "keeps a full state snapshot"),
    # checkpointing rides the chunked scan on the local mesh
    (["--checkpoint-every", "-1"], "--checkpoint-every must be >= 0"),
    (["--checkpoint-every", "4"], "need --checkpoint-dir"),
    (["--resume"], "need --checkpoint-dir"),
    (["--checkpoint-every", "4", "--checkpoint-dir", "/tmp/ck",
      "--shard-clients", "4"], "host npz"),
    (["--checkpoint-every", "4", "--checkpoint-dir", "/tmp/ck",
      "--chunk", "auto"], "fixed --chunk"),
    (["--resume", "--checkpoint-dir", "/tmp/ck", "--no-scan"],
     "chunked scan"),
])
def test_rejected_flag_combinations(argv, match):
    with pytest.raises(SystemExit, match=match):
        validate_flags(_args(*argv))


@pytest.mark.parametrize("argv", [
    ["--async", "--participation", "periodic", "--max-staleness", "2"],
    ["--async", "--participation", "straggler", "--stale-weighting", "exp"],
    ["--participation", "periodic", "--arrival-periods", "1,2,4,1,2,4,1,2"],
    ["--participation", "weighted", "--client-weights", "1,2,3,4,5,6,7,8"],
])
def test_accepted_flag_combinations(argv):
    parsed = validate_flags(_args(*argv))
    assert parsed["kind"] == argv[argv.index("--participation") + 1]


def test_clock_implies_async_rounds():
    parsed = validate_flags(_args("--clock", "constant", "--max-staleness",
                                  "4", "--stale-weighting", "poly"))
    assert parsed["async_rounds"] and parsed["clock_kind"] == "constant"
    assert parsed["kind"] == "full" and parsed["speeds"] is None


def test_client_speeds_parsed_per_client():
    parsed = validate_flags(_args("--clock", "lognormal", "--client-speeds",
                                  "1,2,3,4,1,2,3,4"))
    assert parsed["speeds"] == [1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0]


def test_arrival_periods_parsed_as_ints():
    parsed = validate_flags(_args("--participation", "periodic",
                                  "--arrival-periods", "1,2,4,1,2,4,1,2"))
    assert parsed["periods"] == [1, 2, 4, 1, 2, 4, 1, 2]
    assert not parsed["async_rounds"]  # periodic alone stays synchronous


def test_chunk_parsed_int_and_auto():
    assert validate_flags(_args("--chunk", "16"))["chunk"] == 16
    assert validate_flags(_args("--chunk", "auto"))["chunk"] == "auto"
    assert validate_flags(_args())["chunk"] == 0
    # auto composes with the legacy loop only through --no-scan rejection,
    # not with an int chunk
    assert validate_flags(_args("--chunk", "16", "--no-scan"))["chunk"] == 16


def test_store_resolved():
    assert validate_flags(_args())["store"] == "dense"
    parsed = validate_flags(_args("--participation", "uniform",
                                  "--store", "active"))
    assert parsed["store"] == "active" and parsed["flat"]
    # a clock is a participant source too (capacity bound m)
    assert validate_flags(_args("--clock", "constant", "--store",
                                "active"))["store"] == "active"
    # auto chunking composes with the active store (the tile
    # gather/scatter runs inside every round, chunk-length independent)
    parsed = validate_flags(_args("--participation", "uniform",
                                  "--store", "active", "--chunk", "auto"))
    assert parsed["store"] == "active" and parsed["chunk"] == "auto"


def test_offload_and_aggregate_resolved():
    assert validate_flags(_args())["aggregate"] == "dense"
    parsed = validate_flags(_args("--participation", "uniform",
                                  "--store", "offload"))
    assert parsed["store"] == "offload" and parsed["flat"]
    # a clock is a participant source for the offload tile too
    parsed = validate_flags(_args("--clock", "constant", "--store", "offload",
                                  "--aggregate", "packed"))
    assert parsed["store"] == "offload" and parsed["aggregate"] == "packed"
    # packed rides the device-resident active store as well
    parsed = validate_flags(_args("--participation", "uniform",
                                  "--store", "active",
                                  "--aggregate", "packed"))
    assert parsed["aggregate"] == "packed"


def test_compression_knobs_resolved():
    # "none" resolves to no compressor (the bitwise escape) and no bytes
    parsed = validate_flags(_args())
    assert parsed["compression"] is None
    assert parsed["bandwidth_bps"] is None
    parsed = validate_flags(_args("--compression", "int8",
                                  "--error-feedback"))
    assert parsed["compression"] == "int8" and parsed["error_feedback"]
    # topk default fraction applies only when the flag is omitted
    parsed = validate_flags(_args("--compression", "topk"))
    assert parsed["topk_frac"] == 0.1
    parsed = validate_flags(_args("--compression", "topk",
                                  "--topk-frac", "0.25"))
    assert parsed["topk_frac"] == 0.25
    # the byte clock composes with a codec and with the raw fp32 wire
    parsed = validate_flags(_args("--clock", "constant",
                                  "--bandwidth-bps", "4000"))
    assert parsed["bandwidth_bps"] == 4000.0 and parsed["async_rounds"]
    parsed = validate_flags(_args("--compression", "bf16", "--clock",
                                  "constant", "--bandwidth-bps", "4000"))
    assert parsed["compression"] == "bf16"
    assert parsed["bandwidth_bps"] == 4000.0


def test_fault_knobs_resolved():
    # defaults: no faults, no screening, every fault knob off
    parsed = validate_flags(_args())
    assert parsed["fault_kinds"] == [] and not parsed["screening"]
    assert parsed["clip_norm"] is None and parsed["quorum"] == 0
    assert parsed["deadline_s"] is None and not parsed["watchdog"]
    assert parsed["checkpoint_every"] == 0 and not parsed["resume"]
    # one rate broadcasts over the kinds; per-kind rates parse in order
    parsed = validate_flags(_args("--faults", "crash,nan",
                                  "--fault-rate", "0.2"))
    assert parsed["fault_kinds"] == ["crash", "nan"]
    assert parsed["fault_rates"] == [0.2]
    parsed = validate_flags(_args("--faults", "crash,explode",
                                  "--fault-rate", "0.1,0.3"))
    assert parsed["fault_rates"] == [0.1, 0.3]
    # screening stands alone (real NaN guards) and carries the clip
    parsed = validate_flags(_args("--screening", "--clip-norm", "100"))
    assert parsed["screening"] and parsed["clip_norm"] == 100.0
    # faults/screening are quorum sources in their own right
    assert validate_flags(_args("--faults", "crash",
                                "--quorum", "2"))["quorum"] == 2
    assert validate_flags(_args("--screening",
                                "--quorum", "2"))["quorum"] == 2


def test_deadline_and_watchdog_resolved():
    parsed = validate_flags(_args("--clock", "constant", "--deadline-s",
                                  "2.5", "--quorum", "1"))
    assert parsed["deadline_s"] == 2.5 and parsed["quorum"] == 1
    assert parsed["async_rounds"]  # the clock still implies async rounds
    # watchdog defaults apply only when the tuning flags are omitted
    parsed = validate_flags(_args("--watchdog"))
    assert parsed["watchdog"] and parsed["watchdog_patience"] == 3
    assert parsed["watchdog_factor"] == 2.0
    parsed = validate_flags(_args("--watchdog", "--watchdog-patience", "5",
                                  "--watchdog-factor", "1.5"))
    assert parsed["watchdog_patience"] == 5
    assert parsed["watchdog_factor"] == 1.5


def test_checkpoint_knobs_resolved():
    parsed = validate_flags(_args("--checkpoint-every", "4",
                                  "--checkpoint-dir", "/tmp/ck"))
    assert parsed["checkpoint_every"] == 4 and not parsed["resume"]
    # --resume without --checkpoint-every restores but writes no more
    parsed = validate_flags(_args("--resume", "--checkpoint-dir", "/tmp/ck"))
    assert parsed["resume"] and parsed["checkpoint_every"] == 0
    # a fixed chunk and the offload store both compose with checkpointing
    parsed = validate_flags(_args("--checkpoint-every", "2",
                                  "--checkpoint-dir", "/tmp/ck",
                                  "--chunk", "2"))
    assert parsed["checkpoint_every"] == 2 and parsed["chunk"] == 2
    parsed = validate_flags(_args("--checkpoint-every", "2",
                                  "--checkpoint-dir", "/tmp/ck",
                                  "--store", "offload",
                                  "--participation", "uniform"))
    assert parsed["store"] == "offload" and parsed["checkpoint_every"] == 2


def test_flat_and_kernel_knobs_resolved():
    parsed = validate_flags(_args())
    assert parsed["flat"] and parsed["use_kernel"] is None
    assert not parsed["kernel_interpret"]
    parsed = validate_flags(_args("--no-flat"))
    assert not parsed["flat"]
    assert validate_flags(_args("--kernel", "off"))["use_kernel"] is False
    parsed = validate_flags(_args("--kernel", "interpret"))
    assert parsed["use_kernel"] is True and parsed["kernel_interpret"]
