"""End-to-end behaviour tests: the public drivers do real work on CPU."""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_driver_linreg_end_to_end():
    from repro.launch.train import main as train_main

    argv = [
        "train", "--problem", "linreg", "--algo", "fedgia", "--clients", "16",
        "--k0", "5", "--rounds", "100", "--dim", "40", "--samples", "1600",
        "--tol", "1e-9",
    ]
    old = sys.argv
    sys.argv = argv
    try:
        train_main()
    finally:
        sys.argv = old


def test_train_driver_transformer_loss_improves(tmp_path):
    """Federated LM training on a reduced arch: loss must go DOWN."""
    from repro.launch.train import train

    args = argparse.Namespace(
        problem="linreg", arch="tinyllama-1.1b", reduced=True, algo="fedgia",
        clients=4, k0=3, alpha=1.0, sigma_t=0.3, h_policy="scalar",
        unrolled=False, lr=0.01, rounds=30, tol=0.0, dim=0, samples=0,
        batch=2, seq_len=32, seed=0, log_every=10,
        checkpoint_dir=str(tmp_path / "ck"),
    )
    result = train(args)
    hist = result["history"]
    assert hist[-1]["f"] < hist[0]["f"], (
        f"loss did not improve: {hist[0]['f']} -> {hist[-1]['f']}"
    )
    assert np.isfinite(hist[-1]["f"])
    # checkpoint was written and is reloadable
    from repro.checkpoint import latest_step

    assert latest_step(str(tmp_path / "ck")) == len(hist)


def test_train_driver_no_scan_matches_engine():
    """--no-scan (legacy loop) and the scan engine agree end to end."""
    from repro.launch.train import train

    common = dict(
        problem="linreg", arch=None, reduced=False, algo="fedgia",
        clients=8, k0=3, alpha=0.5, sigma_t=0.2, h_policy="scalar",
        unrolled=False, lr=0.01, rounds=12, tol=0.0, dim=24, samples=480,
        batch=2, seq_len=32, seed=0, log_every=100, checkpoint_dir="",
    )
    res_scan = train(argparse.Namespace(**common))
    res_loop = train(argparse.Namespace(**common, no_scan=True))
    assert res_scan["rounds"] == res_loop["rounds"] == 12
    np.testing.assert_allclose(res_scan["final_f"], res_loop["final_f"],
                               rtol=1e-6)
    np.testing.assert_allclose(res_scan["final_err"], res_loop["final_err"],
                               rtol=1e-5)


def test_serve_driver_end_to_end():
    from repro.launch.serve import serve

    args = argparse.Namespace(
        arch="tinyllama-1.1b", reduced=True, batch=3, prompt_len=12, gen=6,
        long_context=False, seed=0,
    )
    gen = serve(args)
    assert gen.shape == (3, 6)
    assert (gen >= 0).all()
    # the scan-compiled decode loop generates the same tokens as the
    # legacy per-token dispatch
    gen_loop = serve(argparse.Namespace(**vars(args), no_scan=True))
    np.testing.assert_array_equal(gen, gen_loop)


def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import collective_bytes, roofline_terms

    hlo = """
  %all-reduce.1 = f32[8,4096]{1,0} all-reduce(f32[8,4096]{1,0} %x), replica_groups={}
  %ag = bf16[16,128]{1,0} all-gather(bf16[8,128]{1,0} %y), dimensions={0}
  %arstart = f32[100]{0} all-reduce-start(f32[100]{0} %z)
  %ardone = f32[100]{0} all-reduce-done(f32[100]{0} %arstart)
  %add.5 = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    c = collective_bytes(hlo)
    assert c["all-reduce"] == 8 * 4096 * 4 + 400  # start counted once
    assert c["all-gather"] == 16 * 128 * 2
    terms = roofline_terms({"flops": 1e12, "bytes accessed": 1e9}, c)
    assert terms["bottleneck"] in ("compute", "memory", "collective")
    assert terms["t_compute_s"] == pytest.approx(1e12 / 197e12)


def test_dryrun_input_specs_cover_all_modes():
    from repro.config import INPUT_SHAPES
    from repro.configs import get_config
    from repro.launch.dryrun import input_specs

    for arch in ("tinyllama-1.1b", "musicgen-large", "llava-next-mistral-7b"):
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            sds = input_specs(cfg, shape, num_clients=16)
            leaves = jax.tree.leaves(sds)
            assert leaves, f"{arch}/{shape.name}: empty specs"
            for l in leaves:
                assert isinstance(l, jax.ShapeDtypeStruct)
        # vlm/audio: embeds present where required
        if cfg.input_mode != "tokens":
            assert "embeds" in input_specs(cfg, INPUT_SHAPES["train_4k"], 16)
