"""Component-level model tests: MoE dispatch parity, MLA absorbed-form
exactness, RoPE properties, RWKV/SSM recurrence consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import AttnMode, blocked_attention
from repro.models.layers import apply_rope


def test_moe_capacity_matches_dense_oracle():
    """Gather/scatter capacity dispatch == per-expert dense masking when no
    tokens are dropped (generous capacity)."""
    cfg = dataclasses.replace(
        ARCHITECTURES["deepseek-v3-671b"].reduced(),
        dtype="float32", num_experts=4, experts_per_token=2,
    )
    old_cf = moe_lib.CAPACITY_FACTOR
    moe_lib.CAPACITY_FACTOR = 8.0  # no drops
    try:
        params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
        out, aux = moe_lib.moe_apply(params, cfg, x)
        ref = moe_lib.moe_ref_dense(params, cfg, x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
        )
        assert float(aux) >= 0.0
    finally:
        moe_lib.CAPACITY_FACTOR = old_cf


def test_moe_capacity_drops_are_bounded():
    """With tight capacity some tokens drop; output stays finite and close
    to the oracle in aggregate."""
    cfg = dataclasses.replace(
        ARCHITECTURES["arctic-480b"].reduced(), dtype="float32",
        num_experts=4, experts_per_token=2, dense_residual=False,
    )
    params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    out, _ = moe_lib.moe_apply(params, cfg, x)
    assert bool(jnp.isfinite(out).all())


def test_mla_absorbed_equals_naive_fp32():
    """The absorbed decode path is algebraically EXACT in fp32."""
    cfg = dataclasses.replace(
        ARCHITECTURES["deepseek-v3-671b"].reduced(), dtype="float32"
    )
    p = attn_lib.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    pos = jnp.arange(T, dtype=jnp.int32)
    out_train, _ = attn_lib.mla_apply(p, cfg, x, pos, None, AttnMode("train"))

    cache = attn_lib.init_mla_cache(cfg, B, T, jnp.float32)
    _, cache = attn_lib.mla_apply(
        p, cfg, x[:, :5], pos[:5], cache, AttnMode("prefill")
    )
    for t in range(5, T):
        o, cache = attn_lib.mla_apply(
            p, cfg, x[:, t : t + 1], pos[t : t + 1], cache, AttnMode("decode")
        )
        np.testing.assert_allclose(
            np.asarray(o[:, 0]), np.asarray(out_train[:, t]), rtol=1e-4, atol=1e-4
        )


def test_rope_is_relative():
    """RoPE: <q_i, k_j> depends only on i - j."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def score(qi, kj):
        qr = apply_rope(q, jnp.asarray([qi]), 10000.0)
        kr = apply_rope(k, jnp.asarray([kj]), 10000.0)
        return float(jnp.sum(qr * kr))

    assert abs(score(5, 3) - score(12, 10)) < 1e-4
    assert abs(score(0, 0) - score(7, 7)) < 1e-4


def test_blocked_attention_block_size_invariance():
    B, S, H, Kv, hd = 1, 64, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    outs = [
        blocked_attention(q, k, v, pos, pos, block_k=bk) for bk in (8, 32, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(outs[0]), rtol=1e-5, atol=1e-5
        )
    # unrolled variant (dry-run cost pass) is numerically identical
    o_unroll = blocked_attention(q, k, v, pos, pos, block_k=16, unroll=True)
    np.testing.assert_allclose(
        np.asarray(o_unroll), np.asarray(outs[0]), rtol=1e-5, atol=1e-5
    )


def test_scan_layers_false_matches_scan_true():
    """The dry-run analysis mode (unrolled layers) computes the SAME model."""
    from repro.models import Transformer

    cfg = dataclasses.replace(
        ARCHITECTURES["tinyllama-1.1b"].reduced(), dtype="float32"
    )
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 17), 0, cfg.vocab_size)
    m_scan = Transformer(cfg)
    m_unrl = Transformer(dataclasses.replace(cfg, scan_layers=False, remat=False))
    params = m_scan.init(jax.random.PRNGKey(1))
    l1, _ = m_scan.loss(params, {"tokens": toks})
    l2, _ = m_unrl.loss(params, {"tokens": toks})
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_rwkv_chunked_state_equals_full():
    """Processing a sequence in two chunks with state carry == one pass."""
    cfg = ARCHITECTURES["rwkv6-3b"].reduced()
    params = rwkv_lib.time_mix_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.3
    s0 = {
        "shift": jnp.zeros((B, cfg.d_model)),
        "wkv": jnp.zeros((B, cfg.num_heads, cfg.rwkv_head_size,
                          cfg.rwkv_head_size)),
    }
    y_full, _ = rwkv_lib.time_mix_apply(params, cfg, x, s0)
    y1, s1 = rwkv_lib.time_mix_apply(params, cfg, x[:, :7], s0)
    y2, _ = rwkv_lib.time_mix_apply(
        params, cfg, x[:, 7:], {"shift": s1["shift"], "wkv": s1["wkv"]}
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        rtol=2e-4, atol=2e-4,
    )


def test_ssm_chunked_state_equals_full():
    cfg = ARCHITECTURES["hymba-1.5b"].reduced()
    params = ssm_lib.ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.3
    s0 = ssm_lib.init_ssm_state(cfg, B)
    y_full, _ = ssm_lib.ssm_apply(params, cfg, x, s0)
    y1, s1 = ssm_lib.ssm_apply(params, cfg, x[:, :6], s0)
    y2, _ = ssm_lib.ssm_apply(params, cfg, x[:, 6:], s1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        rtol=2e-4, atol=2e-4,
    )
