"""Round-engine equivalence: the scan-compiled chunked driver must be
numerically indistinguishable (fp32 allclose) from the legacy per-round
Python loop — for ALL five algorithms, including the metrics history and
the early-stop round count of the paper's stopping rule (eq. 35)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig
from repro.core import make_algorithm, run_rounds
from repro.core.engine import RoundResult
from repro.data import linreg_noniid
from repro.models import LeastSquares
from repro.utils import pytree as pt

M, N, D = 8, 20, 400
ROUNDS = 24  # >= 20, and not a multiple of the chunk size below
CHUNK = 7    # exercises full + partial chunks

ALGO_SETUPS = {
    "fedgia": dict(sigma_t=0.2, h_policy="scalar", alpha=0.5),
    "fedgia_diag": dict(sigma_t=0.2, h_policy="diag_ema", alpha=0.5),
    "fedavg": dict(lr=0.01, alpha=1.0),
    "fedprox": dict(lr=0.002, prox_mu=1e-4, inner_steps=3, alpha=1.0),
    "fedpd": dict(lr=0.05, fedpd_eta=1.0, inner_steps=3, alpha=1.0),
    "scaffold": dict(lr=0.01, alpha=1.0),
}


@pytest.fixture(scope="module")
def problem():
    batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
    model = LeastSquares(N)
    return model, batch


def _make(problem, key, **overrides):
    model, batch = problem
    name = "fedgia" if key.startswith("fedgia") else key
    kwargs = dict(algorithm=name, num_clients=M, k0=3)
    kwargs.update(ALGO_SETUPS[key])
    kwargs.update(overrides)
    fed = FedConfig(**kwargs)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                      init_batch=batch)
    return algo, state


def _assert_equivalent(res: RoundResult, ref: RoundResult):
    assert res.rounds_run == ref.rounds_run
    assert res.stopped_early == ref.stopped_early
    assert set(res.history) == set(ref.history)
    for k in ref.history:
        np.testing.assert_allclose(res.history[k], ref.history[k],
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    for key in ref.state:
        ok = jax.tree.map(
            lambda a, b: bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-6)),
            res.state[key], ref.state[key],
        )
        assert all(jax.tree.leaves(ok)), f"state[{key!r}] diverged"


@pytest.mark.parametrize("algo_key", sorted(ALGO_SETUPS))
def test_scan_matches_legacy_loop(problem, algo_key):
    """Same seeds -> same metrics history and final state, >= 20 rounds."""
    algo, state = _make(problem, algo_key)
    _, batch = problem
    ref = run_rounds(algo, state, batch, ROUNDS, scan=False)
    res = run_rounds(algo, state, batch, ROUNDS, scan=True, chunk_size=CHUNK)
    assert ref.rounds_run == ROUNDS
    _assert_equivalent(res, ref)


@pytest.mark.parametrize("chunk", [1, 5, 13])
def test_early_stop_round_count_matches(problem, chunk):
    """Device-side tolerance check stops on exactly the same round as the
    host-side check, for chunk sizes that do / do not align with it."""
    algo, state = _make(problem, "fedgia", k0=5)
    _, batch = problem
    ref = run_rounds(algo, state, batch, 300, tol=1e-7, scan=False)
    res = run_rounds(algo, state, batch, 300, tol=1e-7, scan=True,
                     chunk_size=chunk)
    assert ref.stopped_early, "tolerance should be reachable in 300 rounds"
    assert 0 < ref.rounds_run < 300
    _assert_equivalent(res, ref)
    # history is trimmed at the stop round: nothing after it is reported
    assert len(res.history["grad_sq_norm"]) == res.rounds_run
    assert float(res.history["grad_sq_norm"][-1]) < 1e-7


def test_no_early_stop_when_tol_unreachable(problem):
    algo, state = _make(problem, "fedgia")
    _, batch = problem
    res = run_rounds(algo, state, batch, 10, tol=1e-30, scan=True, chunk_size=4)
    assert res.rounds_run == 10 and not res.stopped_early


def test_zero_rounds(problem):
    algo, state = _make(problem, "fedgia")
    _, batch = problem
    res = run_rounds(algo, state, batch, 0)
    assert res.rounds_run == 0 and res.history == {}


def test_metrics_are_stacked_per_round(problem):
    algo, state = _make(problem, "fedavg")
    _, batch = problem
    res = run_rounds(algo, state, batch, 6, scan=True, chunk_size=4)
    for k, v in res.history.items():
        assert v.shape[0] == 6, k
    # cr counts 2 communications per round, in order
    np.testing.assert_allclose(res.history["cr"], 2.0 * np.arange(1, 7))
