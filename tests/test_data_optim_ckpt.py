"""Substrate tests: data pipeline, optimizers, checkpointing, configs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.config import INPUT_SHAPES
from repro.configs import ARCHITECTURES, get_config, list_architectures
from repro.data import dirichlet_partition, equal_partition, linreg_noniid
from repro.data.tokens import synthetic_batch_for
from repro.optim import adam, apply_updates, paper_lr, sgd


def test_linreg_noniid_matches_paper_protocol():
    m, n, d = 16, 32, 800
    batch = linreg_noniid(0, d, n, m)
    assert batch["A"].shape[0] == m
    sizes = batch["mask"].sum(1)
    assert sizes.sum() == d  # all samples assigned exactly once
    base = d / m
    assert sizes.min() >= int(0.5 * base) - 1  # paper's heterogeneous d_i
    assert sizes.max() <= int(1.5 * base) + 1
    # padded rows are zero
    i = int(np.argmin(sizes))
    pad = batch["A"][i][batch["mask"][i] == 0]
    assert (pad == 0).all()


def test_dirichlet_partition_covers_all():
    labels = np.random.default_rng(0).integers(0, 10, 1000)
    parts = dirichlet_partition(labels, 8, alpha=0.5)
    allidx = np.concatenate(parts)
    assert len(allidx) == 1000 and len(np.unique(allidx)) == 1000


def test_equal_partition():
    assert sum(equal_partition(103, 8)) == 103


def test_synthetic_batch_modes():
    for arch in ("tinyllama-1.1b", "musicgen-large", "llava-next-mistral-7b"):
        cfg = ARCHITECTURES[arch].reduced()
        b = synthetic_batch_for(cfg, m=3, batch_per_client=2, seq_len=8)
        lead = jax.tree.leaves(b)[0].shape[:2]
        assert lead == (3, 2)


def test_paper_lr_schedule():
    lr = paper_lr(0.5)
    assert abs(float(lr(jnp.asarray(0))) - 0.5) < 1e-6  # log2(2) = 1
    assert float(lr(jnp.asarray(100))) < 0.08


def test_sgd_adam_reduce_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    for opt in (sgd(0.1), adam(0.2)):
        p = params
        state = opt.init(p)
        for _ in range(200):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            upd, state = opt.update(g, state, p)
            p = apply_updates(p, upd)
        assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "c": jnp.asarray(3)},
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree, extra={"note": "x"})
    assert latest_step(d) == 7
    restored, extra = load_checkpoint(d, 7, tree)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_all_architectures_registered():
    assert len(list_architectures()) == 10
    families = {get_config(a).family for a in list_architectures()}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_param_counts_near_nameplate():
    expect = {
        "arctic-480b": 480e9, "deepseek-v3-671b": 671e9, "deepseek-67b": 67e9,
        "stablelm-12b": 12e9, "llava-next-mistral-7b": 7.2e9,
        "tinyllama-1.1b": 1.1e9, "qwen1.5-0.5b": 0.46e9,
    }
    for arch, target in expect.items():
        got = get_config(arch).param_count()
        assert 0.8 * target < got < 1.25 * target, f"{arch}: {got:.3e}"


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288
    assert INPUT_SHAPES["decode_32k"].kind == "decode"
