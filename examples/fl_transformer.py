"""End-to-end driver: federated training of a ~100M-parameter decoder LM
with FedGiA (a few hundred optimizer steps = rounds x k0).

    PYTHONPATH=src python examples/fl_transformer.py \
        --rounds 40 --k0 5 --clients 4 --batch 2 --seq-len 64

The model (d_model=768, 12 layers, 32k vocab ≈ 134M params) trains on a
synthetic non-iid bigram token stream; the script reports the per-round
objective and verifies it decreases. The identical round function is what
the multi-pod dry-run lowers for the production mesh.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.config import FedConfig, ModelConfig
from repro.core import make_algorithm, run_rounds
from repro.data.tokens import synthetic_batch_for
from repro.models import Transformer


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="fl-lm-134m",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=2048,
        vocab_size=32000,
        dtype="float32",
        source="examples/fl_transformer.py",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--k0", type=int, default=5)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--sigma-t", type=float, default=30.0,
                    help="sigma = t * r_hat / m. The init-point Lipschitz "
                         "probe UNDER-estimates transformer curvature, so t "
                         "must be >> the paper's 0.15 (t=30 ~= the theory's "
                         "sigma >= 6r/m with the true r; t<1 diverges, "
                         "exactly as Lemma IV.1 predicts).")
    args = ap.parse_args()

    cfg = lm_100m()
    model = Transformer(cfg)
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.0f}M")

    batch = jax.tree.map(
        jnp.asarray,
        synthetic_batch_for(cfg, args.clients, args.batch, args.seq_len),
    )
    fed = FedConfig(
        algorithm="fedgia", num_clients=args.clients, k0=args.k0, alpha=1.0,
        sigma_t=args.sigma_t, h_policy="diag_ema", auto_lipschitz=True,
    )
    algo = make_algorithm(fed, model.loss, model=model)
    params0 = model.init(jax.random.PRNGKey(0))
    state = algo.init(params0, jax.random.PRNGKey(1), init_batch=batch)
    print(f"sigma={float(state['sigma']):.4f} r_hat={float(state['r']):.3f}")

    # scan-compiled rounds, 10 per dispatch: the host only surfaces between
    # chunks, where it prints progress and aborts a diverging run early
    # instead of burning the full budget on NaNs
    chunk = 10
    first = None
    r0 = 0
    wall = 0.0
    while r0 < args.rounds:
        res = run_rounds(algo, state, batch, min(chunk, args.rounds - r0))
        state = res.state
        wall += res.wall_s
        for i in range(res.rounds_run):
            r = r0 + i
            f = float(res.history["f_xbar"][i])
            assert f == f and f < 1e4, (
                f"diverged at round {r}: sigma too small (raise --sigma-t)"
            )
            first = first if first is not None else f
            print(f"round {r:3d}  steps={(r+1)*args.k0:4d}  f={f:.4f}  "
                  f"|grad|^2={float(res.history['grad_sq_norm'][i]):.3e}")
        r0 += res.rounds_run
    f = float(res.history["f_xbar"][-1])
    assert f < first, "objective did not improve"
    print(f"OK: {first:.4f} -> {f:.4f} over {args.rounds * args.k0} steps "
          f"({2 * args.rounds} communications, {wall:.0f}s)")


if __name__ == "__main__":
    main()
