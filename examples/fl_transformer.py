"""End-to-end driver: federated training of a ~100M-parameter decoder LM
with FedGiA (a few hundred optimizer steps = rounds x k0).

    PYTHONPATH=src python examples/fl_transformer.py \
        --rounds 40 --k0 5 --clients 4 --batch 2 --seq-len 64

The model (d_model=768, 12 layers, 32k vocab ≈ 134M params) trains on a
synthetic non-iid bigram token stream; the script reports the per-round
objective and verifies it decreases. The identical round function is what
the multi-pod dry-run lowers for the production mesh.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import FedConfig, ModelConfig
from repro.core import make_algorithm
from repro.data.tokens import synthetic_batch_for
from repro.models import Transformer


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="fl-lm-134m",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=2048,
        vocab_size=32000,
        dtype="float32",
        source="examples/fl_transformer.py",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--k0", type=int, default=5)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--sigma-t", type=float, default=30.0,
                    help="sigma = t * r_hat / m. The init-point Lipschitz "
                         "probe UNDER-estimates transformer curvature, so t "
                         "must be >> the paper's 0.15 (t=30 ~= the theory's "
                         "sigma >= 6r/m with the true r; t<1 diverges, "
                         "exactly as Lemma IV.1 predicts).")
    args = ap.parse_args()

    cfg = lm_100m()
    model = Transformer(cfg)
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.0f}M")

    batch = jax.tree.map(
        jnp.asarray,
        synthetic_batch_for(cfg, args.clients, args.batch, args.seq_len),
    )
    fed = FedConfig(
        algorithm="fedgia", num_clients=args.clients, k0=args.k0, alpha=1.0,
        sigma_t=args.sigma_t, h_policy="diag_ema", auto_lipschitz=True,
    )
    algo = make_algorithm(fed, model.loss, model=model)
    params0 = model.init(jax.random.PRNGKey(0))
    state = algo.init(params0, jax.random.PRNGKey(1), init_batch=batch)
    print(f"sigma={float(state['sigma']):.4f} r_hat={float(state['r']):.3f}")

    round_fn = jax.jit(algo.round)
    t0 = time.time()
    first = None
    for r in range(args.rounds):
        state, met = round_fn(state, batch)
        f = float(met["f_xbar"])
        assert f == f and f < 1e4, (
            f"diverged at round {r}: sigma too small (raise --sigma-t)"
        )
        first = first if first is not None else f
        print(f"round {r:3d}  steps={(r+1)*args.k0:4d}  f={f:.4f}  "
              f"|grad|^2={float(met['grad_sq_norm']):.3e}  "
              f"({time.time()-t0:.0f}s)")
    assert f < first, "objective did not improve"
    print(f"OK: {first:.4f} -> {f:.4f} over {args.rounds * args.k0} steps "
          f"({2 * args.rounds} communications)")


if __name__ == "__main__":
    main()
