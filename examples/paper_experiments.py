"""Reproduce the paper's §V experiments end-to-end (compact settings).

    PYTHONPATH=src python examples/paper_experiments.py [--full]

Covers: Table IV (algorithm comparison), Fig. 1 (k0 vs iterations),
Fig. 2 (k0 vs CR/time), Fig. 3 (alpha effect). The heavyweight sweep
behind EXPERIMENTS.md runs via `python -m benchmarks.run`.
"""
import argparse

from benchmarks import fig1_convergence, fig2_k0, fig3_alpha, table4


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all three problems (default: linreg only)")
    args = ap.parse_args()

    print("== Table IV (Obj / CR / time) ==")
    problems = ("linreg", "logreg", "ncvx_logreg") if args.full else ("linreg",)
    rows = table4.run(problems=problems, trials=1)
    for r in rows:
        print(f"  {r['problem']:12s} {r['algo']:9s} k0={r['k0']:<3d}"
              f" obj={r['obj']:.4f} CR={r['cr']:7.1f} t={r['time_s']:.2f}s")

    print("== Fig. 1: k0 vs iterations to converge ==")
    for r in fig1_convergence.run():
        print(f"  k0={r['k0']:<3d} iterations={r['iterations']:<6d}"
              f" rounds={r['rounds']:<5d} f={r['final_obj']:.6f}")

    print("== Fig. 2: k0 vs CR / time ==")
    for r in fig2_k0.run():
        print(f"  {r['variant']:9s} k0={r['k0']:<3d} CR={r['cr']:7.1f}"
              f" t={r['time_s']:.2f}s")

    print("== Fig. 3: alpha vs CR / time ==")
    for r in fig3_alpha.run():
        print(f"  alpha={r['alpha']:<5.2f} CR={r['cr']:<6d} t={r['time_s']:.2f}s"
              f" obj={r['obj']:.6f}")


if __name__ == "__main__":
    main()
