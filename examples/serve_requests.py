"""Batched serving example: mixed-length requests, prefill + decode loop,
greedy sampling, per-phase token accounting.

    PYTHONPATH=src python examples/serve_requests.py --arch tinyllama-1.1b

Uses the reduced config on CPU; the same prefill/decode step functions are
what decode_32k / long_500k lower on the production mesh.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_architectures
from repro.models import Transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=list_architectures())
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # mixed-length requests, left-padded into one batch
    rng = np.random.default_rng(0)
    lens = rng.integers(args.max_prompt // 2, args.max_prompt + 1,
                        args.requests)
    cache_len = args.max_prompt + args.gen
    prompts = np.zeros((args.requests, args.max_prompt), np.int32)
    for i, L in enumerate(lens):
        prompts[i, -L:] = rng.integers(1, cfg.vocab_size, L)
    print(f"arch={cfg.name} requests={args.requests} prompt lens={lens.tolist()}")

    prefill = jax.jit(lambda p, t: model.prefill(p, tokens=t, cache_len=cache_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, jnp.asarray(prompts))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tokens]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.max_prompt + i, jnp.int32)
        logits, cache = decode(params, cache, tokens, pos)
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0

    out = np.asarray(jnp.concatenate(generated, 1))
    tok_s = args.requests * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.requests * args.max_prompt} tokens in {t_prefill:.3f}s")
    print(f"decode : {args.gen - 1} steps in {t_decode:.3f}s "
          f"({tok_s:.1f} tok/s aggregate)")
    for i in range(args.requests):
        print(f"  req{i} -> {out[i].tolist()}")


if __name__ == "__main__":
    main()
