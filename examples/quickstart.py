"""Quickstart: FedGiA on the paper's Example V.1 in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Solves a 128-client non-iid federated least-squares problem to the paper's
tolerance (eq. 35) and contrasts the communication rounds with FedAvg.
"""
import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core import make_algorithm
from repro.data import linreg_noniid
from repro.models import LeastSquares

M, N, D = 128, 100, 12800
TOL = 1e-7

batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
model = LeastSquares(N)

for algo_name, hp in [
    ("fedgia", dict(sigma_t=0.15, h_policy="diag_ema", alpha=0.5)),
    ("fedavg", dict(lr=0.01, alpha=1.0)),
]:
    fed = FedConfig(algorithm=algo_name, num_clients=M, k0=5, **hp)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                      init_batch=batch)
    round_fn = jax.jit(algo.round)
    for r in range(600):
        state, met = round_fn(state, batch)
        if float(met["grad_sq_norm"]) < TOL:
            break
    print(f"{algo_name:8s}: f={float(met['f_xbar']):.6f} "
          f"|grad f|^2={float(met['grad_sq_norm']):.2e} "
          f"CR={2 * (r + 1)} (k0=5, m={M})")
