"""Quickstart: FedGiA on the paper's Example V.1 in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Solves a 128-client non-iid federated least-squares problem to the paper's
tolerance (eq. 35) and contrasts the communication rounds with FedAvg.
Rounds run through the scan-compiled engine (core/engine.py): the stopping
rule is checked on device, so the host never blocks inside the loop.
"""
import jax
import jax.numpy as jnp

from repro.config import FedConfig
from repro.core import make_algorithm, run_rounds
from repro.data import linreg_noniid
from repro.models import LeastSquares

M, N, D = 128, 100, 12800
TOL = 1e-7

batch = {k: jnp.asarray(v) for k, v in linreg_noniid(0, D, N, M).items()}
model = LeastSquares(N)

for algo_name, hp in [
    ("fedgia", dict(sigma_t=0.15, h_policy="diag_ema", alpha=0.5)),
    ("fedavg", dict(lr=0.01, alpha=1.0)),
]:
    fed = FedConfig(algorithm=algo_name, num_clients=M, k0=5, **hp)
    algo = make_algorithm(fed, model.loss, model=model)
    state = algo.init(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(1),
                      init_batch=batch)
    res = run_rounds(algo, state, batch, 600, tol=TOL)
    print(f"{algo_name:8s}: f={float(res.history['f_xbar'][-1]):.6f} "
          f"|grad f|^2={float(res.history['grad_sq_norm'][-1]):.2e} "
          f"CR={2 * res.rounds_run} (k0=5, m={M}, {res.wall_s:.2f}s)")
