#!/usr/bin/env python
"""Docs hygiene check, run from the repo root (CI docs job):

    python tools/check_docs_links.py

Fails if any relative markdown link in docs/ or README.md

  * points at a file that does not exist, or
  * carries a `#fragment` that matches no heading in the target markdown
    file (STALE ANCHOR — e.g. a generated docs/api.md section that was
    renamed or removed).

External http(s)/mailto links are skipped. Heading slugs follow the
GitHub rule (lowercase, punctuation stripped, spaces to hyphens);
headings inside fenced code blocks are ignored. Duplicate-heading
numbering (`#foo-1`) is accepted against the base slug.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.+?)\s*$")
ROOT = Path(__file__).resolve().parent.parent


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, keep [a-z0-9 _-], spaces->'-'."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)  # drops backticks, punctuation, unicode marks
    return s.replace(" ", "-")


def heading_slugs(md: Path) -> set[str]:
    slugs: set[str] = set()
    in_fence = False
    for line in md.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            slugs.add(slugify(m.group(1)))
    return slugs


def check(md: Path, slug_cache: dict) -> list[str]:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, frag = target.partition("#")
        resolved = (md.parent / path).resolve() if path else md
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
            continue
        if frag and resolved.suffix == ".md":
            if resolved not in slug_cache:
                slug_cache[resolved] = heading_slugs(resolved)
            base = re.sub(r"-\d+$", "", frag)
            if frag not in slug_cache[resolved] and base not in slug_cache[resolved]:
                errors.append(
                    f"{md.relative_to(ROOT)}: stale anchor -> {target} "
                    f"(no such heading in {resolved.name})"
                )
    return errors


def main() -> int:
    files = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]
    slug_cache: dict = {}
    errors = [e for f in files if f.exists() for e in check(f, slug_cache)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'FAIL' if errors else 'all links and anchors OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
