#!/usr/bin/env python
"""Fail if any relative markdown link in docs/ or README.md points at a
file that does not exist (external http(s)/mailto links are skipped;
anchors are stripped before the existence check). Run from the repo root:

    python tools/check_docs_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ROOT = Path(__file__).resolve().parent.parent


def check(md: Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def main() -> int:
    files = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]
    errors = [e for f in files if f.exists() for e in check(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'FAIL' if errors else 'all links OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
