#!/usr/bin/env python
"""Gate CI on engine-benchmark regressions.

Compares a freshly produced BENCH_engine.json (benchmarks/run.py --only
engine) against the committed baseline
benchmarks/baselines/BENCH_engine.baseline.json, per engine path
(scan / legacy / sharded / async), on rounds-per-second:

  * FAIL (exit 1) only on a slowdown worse than --max-slowdown (default
    2.5x) — generous on purpose: CI runners are shared and noisy, and
    the point is to catch "someone put a host sync back in the round
    loop", not 20% jitter.
  * WARN on anything worse than --warn-slowdown (default 1.5x).
  * FAIL on a path present in the baseline but missing from the fresh
    run (a silently dropped benchmark is a regression too). Paths only
    in the fresh run are reported as new.

Speedups are fine (they print, so a new baseline can be committed when
they persist). Refresh the baseline with:

    ENGINE_BENCH_ROUNDS=40 PYTHONPATH=src python -m benchmarks.run --only engine
    python tools/check_bench.py --update-baseline

Both files are uploaded as CI artifacts, so the trajectory is diffable
across runs even between baseline refreshes.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE = ROOT / "benchmarks" / "baselines" / "BENCH_engine.baseline.json"


def load_engine_section(path: Path) -> dict:
    """Accept either the full benchmarks/run.py dump ({"engine": {...}})
    or a bare engine-section dict."""
    with open(path) as f:
        data = json.load(f)
    section = data.get("engine", data)
    if "paths" not in section:
        raise SystemExit(f"{path}: no engine benchmark section found")
    return section


def check(current: dict, baseline: dict, max_slowdown: float,
          warn_slowdown: float) -> int:
    failures = warnings = 0
    cur_paths = current["paths"]
    base_paths = baseline["paths"]
    print(f"{'path':<10} {'baseline r/s':>14} {'current r/s':>14} "
          f"{'slowdown':>10}  verdict")
    for name, base in sorted(base_paths.items()):
        if name not in cur_paths:
            print(f"{name:<10} {base['rounds_per_s']:>14.2f} "
                  f"{'MISSING':>14} {'-':>10}  FAIL (path dropped)")
            failures += 1
            continue
        base_rps = float(base["rounds_per_s"])
        cur_rps = float(cur_paths[name]["rounds_per_s"])
        slowdown = base_rps / cur_rps if cur_rps > 0 else float("inf")
        if slowdown > max_slowdown:
            verdict = f"FAIL (> {max_slowdown:g}x)"
            failures += 1
        elif slowdown > warn_slowdown:
            verdict = f"WARN (> {warn_slowdown:g}x)"
            warnings += 1
        else:
            verdict = "ok"
        print(f"{name:<10} {base_rps:>14.2f} {cur_rps:>14.2f} "
              f"{slowdown:>9.2f}x  {verdict}")
    for name in sorted(set(cur_paths) - set(base_paths)):
        print(f"{name:<10} {'-':>14} "
              f"{float(cur_paths[name]['rounds_per_s']):>14.2f} "
              f"{'-':>10}  new (not in baseline)")
    if failures:
        print(f"\n{failures} path(s) regressed beyond {max_slowdown:g}x — "
              f"if intentional, refresh the baseline "
              f"(tools/check_bench.py --update-baseline)", file=sys.stderr)
        return 1
    if warnings:
        print(f"\n{warnings} path(s) slower than {warn_slowdown:g}x baseline "
              f"(within tolerance — watch the artifact trajectory)")
    else:
        print("\nall engine paths within tolerance")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_engine.json",
                    help="freshly produced benchmark json")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--max-slowdown", type=float, default=2.5,
                    help="fail beyond this rounds/s slowdown factor")
    ap.add_argument("--warn-slowdown", type=float, default=1.5,
                    help="warn beyond this rounds/s slowdown factor")
    ap.add_argument("--update-baseline", action="store_true",
                    help="copy --current over --baseline instead of checking")
    args = ap.parse_args()
    if args.update_baseline:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline refreshed from {args.current} -> {args.baseline}")
        return 0
    current = load_engine_section(Path(args.current))
    baseline = load_engine_section(Path(args.baseline))
    return check(current, baseline, args.max_slowdown, args.warn_slowdown)


if __name__ == "__main__":
    sys.exit(main())
