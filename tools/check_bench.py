#!/usr/bin/env python
"""Gate CI on benchmark regressions.

Engine gate: compares a freshly produced BENCH_engine.json
(benchmarks/run.py --only engine) against the committed baseline
benchmarks/baselines/BENCH_engine.baseline.json, per engine path
(scan / scan_pytree / legacy / sharded / async), on rounds-per-second:

  * FAIL (exit 1) only on a slowdown worse than --max-slowdown (default
    2.5x) — generous on purpose: CI runners are shared and noisy, and
    the point is to catch "someone put a host sync back in the round
    loop", not 20% jitter.
  * WARN on anything worse than --warn-slowdown (default 1.5x).
  * FAIL on a path present in the baseline but missing from the fresh
    run (a silently dropped benchmark is a regression too). Paths only
    in the fresh run are reported as new.

Wall-clock gate (--wallclock): compares BENCH_wallclock.json
(benchmarks/wallclock_bench.py) time-to-target per
(algo, spread, weighting) row against
benchmarks/baselines/BENCH_wallclock.baseline.json with the same
fail/warn thresholds. `sim_time_s` is SIMULATED time — deterministic and
machine-independent — so a breach is an algorithmic regression, never
runner noise; a row that converged in the baseline but no longer
converges fails outright, and rows that never converged are skipped
(their sim_time is a round-budget cap, not a time-to-target).

Speedups are fine (they print, so a new baseline can be committed when
they persist). Refresh the baselines with:

    ENGINE_BENCH_ROUNDS=40 PYTHONPATH=src python -m benchmarks.run --only engine --only kernels
    python tools/check_bench.py --update-baseline
    WALLCLOCK_MAX_ROUNDS=400 PYTHONPATH=src python -m benchmarks.run --only wallclock
    python tools/check_bench.py --wallclock --update-baseline

All four files are uploaded as CI artifacts, so the trajectory is
diffable across runs even between baseline refreshes.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE = ROOT / "benchmarks" / "baselines" / "BENCH_engine.baseline.json"
WALLCLOCK_BASELINE = (ROOT / "benchmarks" / "baselines"
                      / "BENCH_wallclock.baseline.json")


def load_engine_section(path: Path) -> dict:
    """Accept either the full benchmarks/run.py dump ({"engine": {...}})
    or a bare engine-section dict."""
    with open(path) as f:
        data = json.load(f)
    section = data.get("engine", data)
    if "paths" not in section:
        raise SystemExit(f"{path}: no engine benchmark section found")
    return section


def check(current: dict, baseline: dict, max_slowdown: float,
          warn_slowdown: float) -> int:
    failures = warnings = 0
    cur_paths = current["paths"]
    base_paths = baseline["paths"]
    print(f"{'path':<10} {'baseline r/s':>14} {'current r/s':>14} "
          f"{'slowdown':>10}  verdict")
    for name, base in sorted(base_paths.items()):
        if name not in cur_paths:
            print(f"{name:<10} {base['rounds_per_s']:>14.2f} "
                  f"{'MISSING':>14} {'-':>10}  FAIL (path dropped)")
            failures += 1
            continue
        base_rps = float(base["rounds_per_s"])
        cur_rps = float(cur_paths[name]["rounds_per_s"])
        slowdown = base_rps / cur_rps if cur_rps > 0 else float("inf")
        if slowdown > max_slowdown:
            verdict = f"FAIL (> {max_slowdown:g}x)"
            failures += 1
        elif slowdown > warn_slowdown:
            verdict = f"WARN (> {warn_slowdown:g}x)"
            warnings += 1
        else:
            verdict = "ok"
        print(f"{name:<10} {base_rps:>14.2f} {cur_rps:>14.2f} "
              f"{slowdown:>9.2f}x  {verdict}")
    for name in sorted(set(cur_paths) - set(base_paths)):
        print(f"{name:<10} {'-':>14} "
              f"{float(cur_paths[name]['rounds_per_s']):>14.2f} "
              f"{'-':>10}  new (not in baseline)")
    if failures:
        print(f"\n{failures} path(s) regressed beyond {max_slowdown:g}x — "
              f"if intentional, refresh the baseline "
              f"(tools/check_bench.py --update-baseline)", file=sys.stderr)
        return 1
    if warnings:
        print(f"\n{warnings} path(s) slower than {warn_slowdown:g}x baseline "
              f"(within tolerance — watch the artifact trajectory)")
    else:
        print("\nall engine paths within tolerance")
    return 0


def load_wallclock_rows(path: Path) -> dict:
    """Index a BENCH_wallclock.json dump by (algo, spread, weighting,
    codec). Pre-compression dumps have no codec field — they key as
    "none", so old baselines stay comparable."""
    with open(path) as f:
        data = json.load(f)
    rows = data.get("rows")
    if rows is None:
        raise SystemExit(f"{path}: no wall-clock benchmark rows found")
    return {(r["algo"], float(r["spread"]), r["weighting"],
             r.get("codec", "none")): r for r in rows}


def check_wallclock(current: dict, baseline: dict, max_slowdown: float,
                    warn_slowdown: float) -> int:
    """Gate simulated time-to-target per (algo, spread, weighting) row."""
    failures = warnings = 0
    print(f"{'algo':<12} {'spread':>6} {'weighting':>9} {'codec':>5} "
          f"{'base t2t':>10} {'cur t2t':>10} {'slowdown':>10}  verdict")
    for key, base in sorted(baseline.items()):
        algo, spread, weighting, codec = key
        label = f"{algo:<12} {spread:>6g} {weighting:>9} {codec:>5}"
        cur = current.get(key)
        if cur is None:
            print(f"{label} {'-':>10} {'MISSING':>10} {'-':>10}  "
                  f"FAIL (row dropped)")
            failures += 1
            continue
        if not base["converged"]:
            print(f"{label} {'-':>10} {'-':>10} {'-':>10}  skip "
                  f"(baseline never reached target)")
            continue
        if not cur["converged"]:
            print(f"{label} {base['sim_time_s']:>10.2f} {'DNF':>10} "
                  f"{'-':>10}  FAIL (no longer converges)")
            failures += 1
            continue
        slowdown = cur["sim_time_s"] / base["sim_time_s"]
        if slowdown > max_slowdown:
            verdict = f"FAIL (> {max_slowdown:g}x)"
            failures += 1
        elif slowdown > warn_slowdown:
            verdict = f"WARN (> {warn_slowdown:g}x)"
            warnings += 1
        else:
            verdict = "ok"
        print(f"{label} {base['sim_time_s']:>10.2f} "
              f"{cur['sim_time_s']:>10.2f} {slowdown:>9.2f}x  {verdict}")
    for key in sorted(set(current) - set(baseline)):
        print(f"{key[0]:<12} {key[1]:>6g} {key[2]:>9} {key[3]:>5} "
              f"new (not in baseline)")
    if failures:
        print(f"\n{failures} wall-clock row(s) regressed — sim_time is "
              f"deterministic, so this is an algorithmic change; if "
              f"intentional, refresh the baseline "
              f"(tools/check_bench.py --wallclock --update-baseline)",
              file=sys.stderr)
        return 1
    if warnings:
        print(f"\n{warnings} row(s) slower than {warn_slowdown:g}x baseline "
              f"(within tolerance)")
    else:
        print("\nall wall-clock rows within tolerance")
    return 0


def update_baseline(current: Path, baseline: Path) -> None:
    """Refresh the committed baseline from a fresh dump, KEEPING the
    baseline's curation keys. Benchmark dumps carry raw numbers only;
    the committed baselines additionally hold hand-written top-level
    `_*` keys (`_meta`: how to regenerate, what the numbers mean). A
    plain file copy silently drops those — every top-level key of the
    old baseline that starts with `_` and is absent from the fresh dump
    is carried over, `_meta` first so the file still reads top-down."""
    with open(current) as f:
        fresh = json.load(f)
    carried = []
    if baseline.exists():
        with open(baseline) as f:
            old = json.load(f)
        carried = [k for k in old if k.startswith("_") and k not in fresh]
        fresh = {**{k: old[k] for k in carried}, **fresh}
    with open(baseline, "w") as f:
        json.dump(fresh, f, indent=2, sort_keys=True)
        f.write("\n")
    kept = f" (kept {', '.join(carried)})" if carried else ""
    print(f"baseline refreshed from {current} -> {baseline}{kept}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_engine.json",
                    help="freshly produced benchmark json")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--wallclock", action="store_true",
                    help="gate BENCH_wallclock.json time-to-target instead "
                         "of the engine round/s")
    ap.add_argument("--max-slowdown", type=float, default=2.5,
                    help="fail beyond this rounds/s slowdown factor")
    ap.add_argument("--warn-slowdown", type=float, default=1.5,
                    help="warn beyond this rounds/s slowdown factor")
    ap.add_argument("--update-baseline", action="store_true",
                    help="refresh --baseline from --current instead of "
                         "checking, preserving the baseline's hand-written "
                         "top-level _meta keys")
    args = ap.parse_args()
    if args.wallclock:
        if args.current == "BENCH_engine.json":
            args.current = "BENCH_wallclock.json"
        if args.baseline == str(BASELINE):
            args.baseline = str(WALLCLOCK_BASELINE)
    if args.update_baseline:
        update_baseline(Path(args.current), Path(args.baseline))
        return 0
    if args.wallclock:
        return check_wallclock(load_wallclock_rows(Path(args.current)),
                               load_wallclock_rows(Path(args.baseline)),
                               args.max_slowdown, args.warn_slowdown)
    current = load_engine_section(Path(args.current))
    baseline = load_engine_section(Path(args.baseline))
    return check(current, baseline, args.max_slowdown, args.warn_slowdown)


if __name__ == "__main__":
    sys.exit(main())
