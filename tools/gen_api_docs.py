#!/usr/bin/env python
"""Generate docs/api.md from the round-engine public surface's docstrings.

The reference covers `repro.core.engine`, `repro.core.selection`,
`repro.core.clock`, `repro.core.compress`, `repro.core.faults`,
`repro.core.api` and
`repro.utils.pytree` — the modules whose docstrings carry the engine
contracts (scan-carry layout, mask contract, staleness fields,
wall-clock event semantics, codec wire formats, the flat-buffer ravel
layout). Symbols are emitted in source order; classes
include their public methods.

    PYTHONPATH=src python tools/gen_api_docs.py            # (re)write
    PYTHONPATH=src python tools/gen_api_docs.py --check    # CI freshness

`--check` exits 1 if docs/api.md does not match what the current
docstrings generate, so a docstring edit that is not regenerated (or a
hand edit to the generated file) fails CI alongside
tools/check_docs_links.py's stale-anchor check.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "docs" / "api.md"
MODULES = ("repro.core.engine", "repro.core.selection", "repro.core.clock",
           "repro.core.compress", "repro.core.faults", "repro.core.api",
           "repro.utils.pytree")

HEADER = """\
# API reference (generated)

Engine-layer public surface, generated from docstrings by
[`tools/gen_api_docs.py`](../tools/gen_api_docs.py) — do **not** edit by
hand (CI regenerates and diffs it). Narrative docs:
[engine.md](engine.md), [async.md](async.md), [paper_map.md](paper_map.md).
"""


def _doc(obj) -> str:
    d = inspect.getdoc(obj)
    return d.strip() if d else "*(no docstring)*"


def _signature(obj) -> str:
    try:
        return str(inspect.signature(obj))
    except (TypeError, ValueError):
        return "(...)"


def _source_line(obj) -> int:
    try:
        return inspect.getsourcelines(obj)[1]
    except (OSError, TypeError):
        return 1 << 30


def _public_members(mod):
    members = []
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue
        members.append((_source_line(obj), name, obj))
    return [(n, o) for _, n, o in sorted(members, key=lambda t: (t[0], t[1]))]


def _class_methods(cls):
    methods = []
    for name, obj in vars(cls).items():
        if name.startswith("_") or name in ("tree_flatten", "tree_unflatten"):
            continue
        fn = None
        if inspect.isfunction(obj):
            fn = obj
        elif isinstance(obj, (classmethod, staticmethod)):
            fn = obj.__func__
        elif isinstance(obj, property):
            fn = obj.fget
        if fn is None or not fn.__doc__:
            continue
        methods.append((_source_line(fn), name, fn, isinstance(obj, property)))
    return sorted(methods, key=lambda t: (t[0], t[1]))


def generate() -> str:
    parts = [HEADER]
    for modname in MODULES:
        mod = importlib.import_module(modname)
        parts.append(f"\n## `{modname}`\n")
        parts.append(_doc(mod))
        parts.append("")
        for name, obj in _public_members(mod):
            parts.append(f"\n### `{name}`\n")
            if inspect.isclass(obj):
                bases = [b.__name__ for b in obj.__bases__
                         if b is not object and b.__name__ != "Protocol"]
                base_s = f"({', '.join(bases)})" if bases else ""
                parts.append(f"```python\nclass {name}{base_s}\n```\n")
                parts.append(_doc(obj))
                for _, mname, fn, is_prop in _class_methods(obj):
                    sig = "" if is_prop else _signature(fn)
                    kind = "property " if is_prop else ""
                    parts.append(f"\n**`{kind}{name}.{mname}{sig}`**\n")
                    parts.append(textwrap.indent(_doc(fn), ""))
            else:
                parts.append(f"```python\n{name}{_signature(obj)}\n```\n")
                parts.append(_doc(obj))
            parts.append("")
    return "\n".join(parts).rstrip() + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail if docs/api.md is stale instead of writing")
    args = ap.parse_args()
    text = generate()
    if args.check:
        current = OUT.read_text() if OUT.exists() else ""
        if current != text:
            # point at the first drifted line so the CI log says WHAT is
            # stale, not just that something is
            cur_lines = current.splitlines()
            new_lines = text.splitlines()
            for i, (a, b) in enumerate(zip(cur_lines, new_lines), 1):
                if a != b:
                    print(f"first difference at docs/api.md:{i}\n"
                          f"  committed: {a!r}\n"
                          f"  generated: {b!r}", file=sys.stderr)
                    break
            else:
                n_cur, n_new = len(cur_lines), len(new_lines)
                print(f"docs/api.md line count drifted: committed {n_cur} "
                      f"lines, generated {n_new}", file=sys.stderr)
            print("docs/api.md is stale — regenerate with "
                  "`PYTHONPATH=src python tools/gen_api_docs.py`",
                  file=sys.stderr)
            return 1
        print("docs/api.md is up to date")
        return 0
    OUT.write_text(text)
    print(f"wrote {OUT.relative_to(ROOT)} ({len(text.splitlines())} lines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
